"""Registry-backed population (docs/population.md).

Four layers of coverage:

1. Pure units: :class:`ClientRegistry` gather/scatter (a scatter touches
   exactly its rows, every other row stays bitwise intact), lazy adapter
   sharding, the splitmix64 data-seed column, :class:`CohortSampler`
   strategies + eligibility filters, and :class:`AvailabilityCursors`
   against a brute-force interval check.
2. The churn-trace versions: v1 is golden-anchored bit-exactly (old
   seeds stay reproducible), v2 is structurally valid + deterministic
   and shares v1's churny-client selection.
3. Bit-identity: ``population=PopulationConfig(registered=n_clients)``
   reproduces the legacy dict path's history exactly — on the plain
   loop, on the sync runtime policy, and against the pre-refactor
   golden (``tests/golden/bert_parity.json``).
4. Population-scale runs: sampled cohorts on all three scheduler
   policies, registry write-backs, checkpoint/resume (including the
   presence-mismatch errors), telemetry ``population.*`` gauges, and a
   sharded-mesh smoke (skipped below 2 devices).
"""
import json
import os

import jax
import numpy as np
import pytest

import repro.checkpoint.federation as fedckpt
from repro import telemetry as tm
from repro.checkpoint import CheckpointConfig, tree_equal
from repro.data.pipeline import CountingIterator, infinite_batches
from repro.federation.simulation import FedConfig, Federation
from repro.federation.topology import make_churn_trace
from repro.population import (AvailabilityCursors, ClientRegistry,
                              CohortSampler, PopulationConfig,
                              PopulationRuntime)
from repro.population.registry import SCALAR_COLUMNS, mix64
from repro.runtime import RuntimeConfig

TINY = dict(n_clients=4, n_edges=2, alpha=5.0, poisoned=(),
            total_examples=200, probe_q=8, local_warmup_steps=1,
            layers=4, t_rounds=1, batch_size=8, seed=0, seq_len=16,
            num_classes=4, use_channel=False)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "bert_parity.json")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_defaults_and_data_seed_column():
    reg = ClientRegistry(100, adapter_dim=6, shard_rows=16, seed=3)
    for name, dt, fill in SCALAR_COLUMNS:
        col = getattr(reg, name)
        assert col.dtype == np.dtype(dt) and len(col) == 100
        if name != "data_seed":
            assert (col == fill).all()
    # splitmix64 data seeds: deterministic in (id, seed), all distinct
    np.testing.assert_array_equal(reg.data_seed,
                                  mix64(np.arange(100), salt=3))
    assert len(np.unique(reg.data_seed)) == 100
    reg2 = ClientRegistry(100, seed=4)
    assert (reg.data_seed != reg2.data_seed).any()
    with pytest.raises(ValueError):
        ClientRegistry(0)
    with pytest.raises(ValueError):
        ClientRegistry(8, shard_rows=0)
    with pytest.raises(AttributeError):
        reg.not_a_column


def test_registry_scatter_touches_exactly_its_rows():
    rng = np.random.default_rng(0)
    reg = ClientRegistry(50, adapter_dim=4, shard_rows=8)
    before = {k: v.copy() for k, v in reg.columns.items()}
    ids = rng.choice(50, 7, replace=False)
    reg.scatter(ids, trust=rng.random(7), last_round=np.arange(7))
    others = np.setdiff1d(np.arange(50), ids)
    for name in reg.columns:
        np.testing.assert_array_equal(reg.columns[name][others],
                                      before[name][others])
    got = reg.gather(ids, columns=("trust", "last_round"))
    assert set(got) == {"trust", "last_round"}
    np.testing.assert_array_equal(got["last_round"], np.arange(7))
    with pytest.raises(IndexError):
        reg.gather([50])
    with pytest.raises(IndexError):
        reg.scatter([-1], trust=[0.5])


def test_registry_adapter_shards_allocate_lazily():
    reg = ClientRegistry(40, adapter_dim=3, shard_rows=16)
    assert reg.n_shards == 3 and reg.allocated_shards == 0
    scalars = reg.nbytes
    # reads never allocate: untouched rows are zero
    np.testing.assert_array_equal(reg.gather_adapters([0, 17, 39]),
                                  np.zeros((3, 3), np.float32))
    assert reg.allocated_shards == 0 and reg.nbytes == scalars
    # a scatter allocates exactly the shards it lands in (the tail
    # shard is short: rows 32..39)
    reg.scatter_adapters([1, 39], np.arange(6, dtype=np.float32)
                         .reshape(2, 3))
    assert reg.allocated_shards == 2
    assert reg.has_adapter_shard(0) and reg.has_adapter_shard(2)
    assert not reg.has_adapter_shard(1)
    assert reg.nbytes == scalars + (16 + 8) * 3 * 4
    got = reg.gather_adapters([39, 1, 2])
    np.testing.assert_array_equal(got[0], [3.0, 4.0, 5.0])
    np.testing.assert_array_equal(got[1], [0.0, 1.0, 2.0])
    np.testing.assert_array_equal(got[2], np.zeros(3))
    with pytest.raises(ValueError):
        reg.scatter_adapters([1, 2], np.zeros((2, 4)))


def test_registry_state_roundtrip_and_mismatch():
    rng = np.random.default_rng(1)
    reg = ClientRegistry(30, adapter_dim=5, shard_rows=8, seed=9)
    reg.scatter(np.arange(10), trust=rng.random(10),
                participations=rng.integers(0, 9, 10))
    reg.scatter_adapters([3, 21], rng.random((2, 5)).astype(np.float32))
    other = ClientRegistry(30, adapter_dim=5, shard_rows=8, seed=9)
    other.load_state(reg.state())
    for name in reg.columns:
        np.testing.assert_array_equal(other.columns[name],
                                      reg.columns[name])
    assert other.allocated_shards == reg.allocated_shards
    np.testing.assert_array_equal(other.gather_adapters(np.arange(30)),
                                  reg.gather_adapters(np.arange(30)))
    with pytest.raises(ValueError, match="registered"):
        ClientRegistry(31, adapter_dim=5, shard_rows=8) \
            .load_state(reg.state())
    with pytest.raises(ValueError, match="shard_rows"):
        ClientRegistry(30, adapter_dim=5, shard_rows=16) \
            .load_state(reg.state())


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------

def _sampler(registered, **cfg_kw):
    cfg = PopulationConfig(registered=registered, **cfg_kw)
    return CohortSampler(ClientRegistry(registered), cfg)


def test_config_validation():
    with pytest.raises(ValueError, match="strategy"):
        PopulationConfig(registered=8, strategy="lottery")
    with pytest.raises(ValueError):
        PopulationConfig(registered=0)
    with pytest.raises(ValueError):
        PopulationConfig(registered=8, staleness_beta=1.5)
    with pytest.raises(ValueError, match="churn"):
        PopulationConfig(registered=8,
                         churn=make_churn_trace(4, 100.0, seed=0))


def test_identity_fast_path_draws_no_rng():
    s = _sampler(6, seed=5)
    for g in (0, 1, 7):
        np.testing.assert_array_equal(s.sample(g, 6), np.arange(6))
    assert s.last_eligible == 6
    with pytest.raises(ValueError, match="cohort"):
        s.sample(0, 7)


def test_uniform_sampling_is_stateless_and_round_keyed():
    s = _sampler(100, seed=11)
    a = s.sample(3, 10)
    assert len(a) == 10 and len(np.unique(a)) == 10
    assert a.min() >= 0 and a.max() < 100 and (np.diff(a) > 0).all()
    # stateless: re-sampling the same round is a pure function
    np.testing.assert_array_equal(a, _sampler(100, seed=11).sample(3, 10))
    assert (a != s.sample(4, 10)).any()
    assert (a != _sampler(100, seed=12).sample(3, 10)).any()


def test_round_robin_covers_population():
    s = _sampler(10, strategy="round-robin")
    seen = set()
    for g in range(5):
        ids = s.sample(g, 4)
        assert len(ids) == 4
        seen.update(ids.tolist())
    assert seen == set(range(10))


def test_min_trust_filter_and_top_up():
    s = _sampler(20, min_trust=0.5, seed=0)
    s.registry.trust[:] = 0.1
    good = np.array([2, 5, 11, 17])
    s.registry.trust[good] = 0.9
    # exactly enough eligible: the cohort is the eligible set
    np.testing.assert_array_equal(s.sample(0, 4), good)
    assert s.last_eligible == 4
    # under-filled: tops up with the highest-trust ineligible clients
    s.registry.trust[3] = 0.4
    ids = s.sample(1, 6)
    assert len(ids) == 6 and set(good) < set(ids.tolist()) \
        and 3 in ids.tolist()


def test_churn_filter_excludes_offline_clients():
    trace = make_churn_trace(12, 400.0, mean_on_s=30.0, mean_off_s=30.0,
                             seed=2)
    s = _sampler(12, churn=trace, seed=0)
    cursors = AvailabilityCursors(trace)
    for t in (0.0, 50.0, 125.0, 300.0):
        online = np.flatnonzero(cursors.online_mask(t))
        if len(online) >= 4:
            ids = s.sample(int(t), 4, t=t)
            assert set(ids.tolist()) <= set(online.tolist())


def test_availability_cursors_match_brute_force():
    trace = make_churn_trace(30, 500.0, mean_on_s=20.0, mean_off_s=15.0,
                             churn_frac=0.8, seed=4)
    cur = AvailabilityCursors(trace)

    def brute(t):
        return np.array([not any(s <= t < e for s, e in iv)
                         for iv in trace.offline])

    ts = np.sort(np.random.default_rng(0).uniform(0, 600, 40))
    for t in ts:                       # monotone (the O(1) fast path)
        np.testing.assert_array_equal(cur.online_mask(t), brute(t))
    np.testing.assert_array_equal(cur.online_mask(10.0), brute(10.0))
    np.testing.assert_array_equal(cur.online_mask(450.0), brute(450.0))


# ---------------------------------------------------------------------------
# churn-trace versions
# ---------------------------------------------------------------------------

#: make_churn_trace(4, 200.0, seed=3, version=1) captured before the
#: vectorized v2 landed — v1 must reproduce these bits forever.
_CHURN_V1_GOLDEN = {
    0: [[132.00888574688284, 138.8787621302493],
        [154.34889351456215, 163.03982379588243],
        [169.19541999812702, 189.21465133730075],
        [193.19313370035783, 202.2183413554611]],
    1: [[15.991882729581105, 41.69881185949355],
        [72.65977579383542, 113.34488662778213],
        [167.80506939727127, 197.50129201537754]],
    2: [[10.663633437617165, 10.699624017463428],
        [80.60112034307438, 98.48957510278525],
        [171.46151081505906, 184.53332942022425]],
    3: [[35.38829893028421, 47.06694670697417],
        [49.547981189669066, 92.68543623829572],
        [177.54756807554335, 178.85913618232942]],
}


def test_churn_v1_matches_golden():
    tr = make_churn_trace(4, 200.0, seed=3, version=1)
    for n, want in _CHURN_V1_GOLDEN.items():
        np.testing.assert_allclose(tr.offline[n], np.asarray(want),
                                   rtol=0, atol=0)


def test_churn_v2_structure_and_determinism():
    tr = make_churn_trace(200, 300.0, churn_frac=0.5, seed=7)
    tr2 = make_churn_trace(200, 300.0, churn_frac=0.5, seed=7)
    v1 = make_churn_trace(200, 300.0, churn_frac=0.5, seed=7, version=1)
    # both versions draw the churny subset first from the same stream
    churny = set(np.random.default_rng(7)
                 .choice(200, 100, replace=False).tolist())
    for n in range(200):
        iv = tr.offline[n]
        np.testing.assert_array_equal(iv, tr2.offline[n])
        if n not in churny:
            assert len(iv) == 0 and len(v1.offline[n]) == 0
            continue
        if len(iv) == 0:               # first on-dwell outran the horizon
            continue
        assert (iv[:, 1] > iv[:, 0]).all()         # non-empty intervals
        assert (np.diff(iv[:, 0]) > 0).all()       # sorted starts
        assert (iv[1:, 0] >= iv[:-1, 1]).all()     # non-overlapping
        assert iv[0, 0] > 0 and iv[0, 0] < 300.0   # starts online
    assert sum(len(tr.offline[n]) > 0 for n in churny) >= 90
    with pytest.raises(ValueError):
        make_churn_trace(4, 100.0, version=3)


def test_churn_versions_same_distribution():
    kw = dict(mean_on_s=40.0, mean_off_s=20.0, seed=1)
    n1 = np.mean([len(iv) for iv in
                  make_churn_trace(400, 600.0, version=1, **kw).offline])
    n2 = np.mean([len(iv) for iv in
                  make_churn_trace(400, 600.0, version=2, **kw).offline])
    assert abs(n1 - n2) / n1 < 0.15, (n1, n2)


# ---------------------------------------------------------------------------
# bit-identity with the legacy dict path
# ---------------------------------------------------------------------------

def _history(population, runtime=None, **run_kw):
    fed = Federation(FedConfig(**TINY), backend="batched")
    h = fed.run("fedavg", global_rounds=2, steps_per_round=2,
                runtime=runtime, population=population, **run_kw)
    return fed, h


def test_identity_population_is_bit_inert_plain_loop():
    fed0, h0 = _history(None)
    fed1, h1 = _history(PopulationConfig(registered=TINY["n_clients"]))
    assert h0["accuracy"] == h1["accuracy"]
    assert h0["loss"] == h1["loss"] and h0["delta"] == h1["delta"]
    assert tree_equal(fed0.last_theta, fed1.last_theta)
    # and the registry saw the rounds: everyone trained every round
    reg = fed1._population.registry
    assert (reg.participations == 2).all() and (reg.last_round == 1).all()


def test_identity_population_is_bit_inert_sync_runtime():
    fed0, h0 = _history(None, runtime=RuntimeConfig(policy="sync"))
    fed1, h1 = _history(PopulationConfig(registered=TINY["n_clients"]),
                        runtime=RuntimeConfig(policy="sync"))
    assert h0["accuracy"] == h1["accuracy"] and h0["time"] == h1["time"]
    assert h0["trace"].records == h1["trace"].records
    assert tree_equal(fed0.last_theta, fed1.last_theta)


def test_identity_population_matches_prerefactor_golden_config():
    """Golden anchor, transitively: on the exact pre-refactor golden
    config (``tests/golden/bert_parity.json`` — full elsa stack:
    clustering, dynamic splits, SS-OP∘sketch channel, screening), an
    identity population reproduces the legacy path's history bitwise.
    ``test_split_api`` pins that legacy history to the golden file, so
    wherever the environment reproduces the golden, this run does too."""
    gold = json.load(open(GOLDEN))
    kw = dict(gold["config"])
    if "bert_layers" in kw:
        kw["layers"] = kw.pop("bert_layers")   # golden predates the rename
    kw["poisoned"] = tuple(kw.get("poisoned", ()))
    run_kw = dict(global_rounds=gold["run"]["global_rounds"],
                  steps_per_round=gold["run"]["steps_per_round"])
    fed0 = Federation(FedConfig(**kw), backend="batched")
    h0 = fed0.run(gold["run"]["method"], **run_kw)
    fed1 = Federation(FedConfig(**kw), backend="batched")
    h1 = fed1.run(gold["run"]["method"],
                  population=PopulationConfig(registered=kw["n_clients"]),
                  **run_kw)
    assert h0["loss"] == h1["loss"]
    assert h0["accuracy"] == h1["accuracy"]
    assert h0["delta"] == h1["delta"]
    assert h0["client_losses"] == h1["client_losses"]
    np.testing.assert_array_equal(fed0.trust_ledger.scores,
                                  fed1.trust_ledger.scores)
    assert tree_equal(fed0.last_theta, fed1.last_theta)


# ---------------------------------------------------------------------------
# population-scale runs (registered > slots)
# ---------------------------------------------------------------------------

def test_population_run_updates_registry():
    fed, h = _history(PopulationConfig(registered=12, seed=3))
    assert np.isfinite(h["loss"]).all()
    reg = fed._population.registry
    # 2 rounds x 4 slots of participations, attributed to sampled ids
    assert reg.participations.sum() == 8
    trained = np.flatnonzero(reg.participations > 0)
    assert (reg.last_round[trained] >= 0).all()
    assert (reg.last_round[reg.participations == 0] == -1).all()
    assert (reg.n_examples[trained] > 0).all()
    # trained clients carry non-zero adapter deltas in the lazy column
    assert fed._population.registry.allocated_shards >= 1
    deltas = reg.gather_adapters(trained)
    assert (np.abs(deltas).sum(axis=1) > 0).all()
    # edge/cluster columns were seeded for the bootstrap cohort
    assert (reg.edge[:TINY["n_clients"]] >= 0).all()


def test_population_validation_against_federation():
    fed = Federation(FedConfig(**TINY), backend="batched")
    with pytest.raises(ValueError, match="registered"):
        fed.run("fedavg", global_rounds=1,
                population=PopulationConfig(registered=2))
    with pytest.raises(ValueError, match="cohort"):
        fed.run("fedavg", global_rounds=1,
                population=PopulationConfig(registered=8, cohort=6))


def test_synthesized_data_is_per_id_deterministic_and_lru_exact():
    fed = Federation(FedConfig(**TINY), backend="batched")
    pop = PopulationRuntime(fed, PopulationConfig(registered=40,
                                                  data_cache=4))
    # ids below n_clients reuse the legacy datasets by construction
    assert pop.data_for(1) is fed.data[1]
    d = pop.data_for(20)
    assert len(d.tokens) == len(d.labels) > 0
    pop2 = PopulationRuntime(fed, PopulationConfig(registered=40,
                                                   data_cache=4))
    np.testing.assert_array_equal(d.tokens, pop2.data_for(20).tokens)
    np.testing.assert_array_equal(d.labels, pop2.data_for(20).labels)
    # iterator streams survive LRU eviction bit-exactly: draw 3, evict
    # by touching other ids, then the next draw matches an
    # uninterrupted reference stream's 4th batch
    it = pop.iter_for(20)
    for _ in range(3):
        next(it)
    for cid in (21, 22, 23, 24, 25):
        next(pop.iter_for(cid))
    assert 20 not in pop._iters          # evicted; cursor in registry
    assert pop.registry.draws[20] == 3
    got = next(pop.iter_for(20))
    ref = CountingIterator(infinite_batches(
        d.tokens, d.labels, TINY["batch_size"], seed=TINY["seed"] + 120))
    for _ in range(3):
        next(ref)
    want = next(ref)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])


@pytest.mark.parametrize("policy", ["sync", "deadline", "async"])
def test_population_runs_on_every_scheduler(policy):
    fed, h = _history(PopulationConfig(registered=16, seed=1),
                      runtime=RuntimeConfig(policy=policy))
    assert np.isfinite(h["loss"]).all()
    reg = fed._population.registry
    assert reg.participations.sum() > 0
    assert (reg.trust >= 0).all()


def test_population_telemetry_gauges():
    with tm.session() as tel:
        _history(PopulationConfig(registered=12, seed=3))
    assert tel.gauge("population.registered") == 12
    assert tel.gauge("population.eligible") == 12
    assert tel.gauge("population.sampled") == TINY["n_clients"]
    assert tel.gauge("population.registry_bytes") > 0
    assert tel.gauge("population.adapter_shards") >= 1


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------

def test_population_checkpoint_resume_is_bit_identical(tmp_path):
    d = str(tmp_path / "ck")
    pop_kw = dict(registered=12, seed=3)
    fedA, hA = _history(PopulationConfig(**pop_kw),
                        checkpoint=CheckpointConfig(dir=d, keep=9))
    fedB, hB = _history(PopulationConfig(**pop_kw),
                        resume_from=fedckpt.round_path(d, 0))
    assert hA["accuracy"] == hB["accuracy"]
    assert hA["loss"] == hB["loss"] and hA["delta"] == hB["delta"]
    assert tree_equal(fedA.last_theta, fedB.last_theta)
    ra, rb = fedA._population.registry, fedB._population.registry
    for name in ra.columns:
        np.testing.assert_array_equal(ra.columns[name], rb.columns[name])
    np.testing.assert_array_equal(
        ra.gather_adapters(np.arange(12)),
        rb.gather_adapters(np.arange(12)))


def test_population_checkpoint_presence_mismatch(tmp_path):
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    _history(None, checkpoint=CheckpointConfig(dir=d1, keep=9))
    with pytest.raises(ValueError, match="population"):
        _history(PopulationConfig(registered=12),
                 resume_from=fedckpt.round_path(d1, 0))
    _history(PopulationConfig(registered=12, seed=3),
             checkpoint=CheckpointConfig(dir=d2, keep=9))
    with pytest.raises(ValueError, match="population"):
        _history(None, resume_from=fedckpt.round_path(d2, 0))
    with pytest.raises(ValueError, match="registered"):
        _history(PopulationConfig(registered=13, seed=3),
                 resume_from=fedckpt.round_path(d2, 0))


# ---------------------------------------------------------------------------
# sharded mesh
# ---------------------------------------------------------------------------

@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices (XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
def test_population_on_sharded_mesh():
    from repro.launch.mesh import make_federation_mesh
    kw = dict(TINY, n_clients=8)
    fed = Federation(FedConfig(**kw), backend="batched",
                     mesh=make_federation_mesh())
    h = fed.run("fedavg", global_rounds=2, steps_per_round=2,
                population=PopulationConfig(registered=24, seed=5))
    assert np.isfinite(h["loss"]).all()
    assert fed._population.registry.participations.sum() == 16
