"""Unit tests for the ELSA core modules (Eqs. 4–24)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core import clustering as clus
from repro.core import comm_model as cm
from repro.core import splitting as sp
from repro.core import ssop as ssop_mod
from repro.core import trust as trust_mod
from repro.core.fingerprint import (Fingerprint, divergence_matrix,
                                    fingerprint, kl_gaussian, sym_kl)
from repro.core.sketch import compress, decompress, make_plan


def test_fingerprint_kl_properties():
    a = fingerprint(jax.random.normal(jax.random.PRNGKey(0), (64, 12)))
    b = fingerprint(3.0 + jax.random.normal(jax.random.PRNGKey(1), (64, 12)))
    assert abs(float(kl_gaussian(a, a))) < 1e-3
    assert float(kl_gaussian(a, b)) > 1.0
    assert abs(float(sym_kl(a, b)) - float(sym_kl(b, a))) < 1e-3


def test_divergence_matrix_shape_and_symmetry():
    fps = [fingerprint(jax.random.normal(jax.random.PRNGKey(i), (32, 8)))
           for i in range(4)]
    d = divergence_matrix(fps)
    assert d.shape == (4, 4)
    np.testing.assert_allclose(d, d.T, atol=1e-6)
    assert (np.diag(d) == 0).all()


def test_trust_downweights_outlier():
    n = 6
    div = np.full((n, n), 1.0)
    np.fill_diagonal(div, 0.0)
    div[5, :] = div[:, 5] = 10.0   # behavioral outlier
    div[5, 5] = 0.0
    norms = np.full((n, 16), 10.0)
    t = trust_mod.trust_scores(div, norms)
    assert t[5] < t[:5].min()


def test_clustering_groups_similar_clients():
    rng = np.random.default_rng(0)
    n, k = 12, 3
    div = np.abs(rng.normal(5, 0.5, (n, n)))
    div = (div + div.T) / 2
    np.fill_diagonal(div, 0)
    for g in range(3):
        idx = np.arange(4 * g, 4 * g + 4)
        div[np.ix_(idx, idx)] *= 0.02
    trust = np.ones(n)
    lat = np.full((n, k), 500.0)
    for g in range(3):
        lat[4 * g:4 * g + 4, g] = 30.0
    res = clus.cluster_clients(div, trust, lat, tau_max=200.0, w_min=0.1)
    for g in range(3):
        members = res.groups[g]
        assert set(members) == set(range(4 * g, 4 * g + 4))


def test_clustering_excludes_unreachable():
    div = np.zeros((3, 3))
    trust = np.ones(3)
    lat = np.array([[50.0], [60.0], [900.0]])
    res = clus.cluster_clients(div, trust, lat, tau_max=200.0, w_min=0.1)
    assert res.assignment[2] is None


def test_split_policy_bounds_and_privacy():
    pol = sp.SplitPolicy(num_blocks=12, o_fix=2, p_min=1, p_max=6)
    for h, bw in [(1e9, 1e6), (1e12, 1e9), (5e10, 5e7)]:
        p, q, o = sp.split_for_client(h, bw, 1e12, 1e9, pol)
        assert 1 <= p <= 6 and o == 2 and p + q + o == 12
    # weak compute + fat uplink -> offload more (small p)
    p_weak, _, _ = sp.split_for_client(1e9, 1e9, 1e12, 1e9, pol)
    p_strong, _, _ = sp.split_for_client(1e12, 1e6, 1e12, 1e9, pol)
    assert p_weak <= p_strong


def test_ssop_orthogonal_and_exact_inverse():
    j = jax.random.normal(jax.random.PRNGKey(0), (50, 48))
    so = ssop_mod.make_ssop(j, 8, "salt", 3)
    q = ssop_mod.q_matrix(so)
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(48), atol=1e-5)
    h = jax.random.normal(jax.random.PRNGKey(1), (10, 48))
    np.testing.assert_allclose(
        np.asarray(ssop_mod.apply_ssop_inverse(ssop_mod.apply_ssop(h, so), so)),
        np.asarray(h), atol=1e-5)


def test_ssop_seed_determinism_and_secrecy():
    v1 = ssop_mod.random_orthogonal(8, ssop_mod.client_seed("s", 1))
    v1b = ssop_mod.random_orthogonal(8, ssop_mod.client_seed("s", 1))
    v2 = ssop_mod.random_orthogonal(8, ssop_mod.client_seed("s", 2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v1b))
    assert float(jnp.abs(v1 - v2).max()) > 0.1


def test_sketch_roundtrip_identity_when_lossless():
    """Z == D with Y=1 is a signed permutation -> exact recovery."""
    plan = make_plan(16, 1, 16, seed=1)
    # force injective buckets
    import jax.numpy as jnp2
    plan = plan._replace(bucket=jnp2.arange(16, dtype=jnp2.int32)[None, :])
    h = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    rec = decompress(compress(h, plan), plan)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(h), atol=1e-6)


def test_sketch_error_grows_with_rho():
    h = jax.random.normal(jax.random.PRNGKey(0), (32, 256))
    errs = []
    for z in (128, 32, 8):
        plan = make_plan(256, 3, z, seed=2)
        rec = decompress(compress(h, plan), plan)
        errs.append(float(jnp.linalg.norm(rec - h) / jnp.linalg.norm(h)))
    assert errs[0] < errs[1] < errs[2]


def test_edge_weight_and_cloud_aggregate():
    assert agg.edge_weight(0.0, 1.0) == 1.0
    assert agg.edge_weight(1.0, 1.0) == 0.5
    trees = {0: {"w": jnp.ones(3)}, 1: {"w": 3 * jnp.ones(3)}}
    out = agg.cloud_aggregate(trees, {0: 1.0, 1: 1.0})
    np.testing.assert_allclose(np.asarray(out["w"]), 2 * np.ones(3))


def test_convergence_criterion():
    a = {"w": jnp.zeros(4)}
    b = {"w": jnp.full(4, 1e-6)}
    assert agg.converged(a, b, xi=1e-3)
    assert not agg.converged(a, {"w": jnp.ones(4)}, xi=1e-3)


def test_comm_model_eq22_24():
    cc = cm.CommConfig(t_rounds=2, bytes_per_param=4, seq_len=128,
                       d_hidden=768, rho=2.0, lora_bytes=1_000_000)
    vol = cm.round_volume_bytes(cc, {0: [8, 8], 1: [16]}, n_edges=2)
    expect = 2 * 2 * 4 * 128 * 768 / 2.0 * 32 + 2 * 1_000_000
    assert abs(vol - expect) < 1e-6
    t = cm.client_comm_time(cc, 8, 1e7)
    assert abs(t - (2 * 2 * 8 * 128 * 4 * 768 / 2.0) / 1e7) < 1e-9
    total = cm.total_comm_time(cc, [8, 16], [1e7, 1e7], 10)
    assert total == 10 * cm.client_comm_time(cc, 16, 1e7)


def test_comm_model_monotonicity_and_straggler_bound():
    import dataclasses

    base = cm.CommConfig(t_rounds=2, bytes_per_param=4, seq_len=64,
                         d_hidden=768, rho=1.0, lora_bytes=500_000)
    # Eq. 23: time strictly decreases as rho grows (more compression)...
    times = [cm.client_comm_time(dataclasses.replace(base, rho=r), 16, 1e7)
             for r in (1.0, 2.0, 3.3, 8.0)]
    assert all(a > b for a, b in zip(times, times[1:]))
    # ...and as bandwidth grows
    bws = [cm.client_comm_time(base, 16, bw) for bw in (1e6, 1e7, 1e8)]
    assert all(a > b for a, b in zip(bws, bws[1:]))
    # Eq. 24 is the straggler max: total >= G * every client's own time
    batches, bands = [8.0, 16.0, 24.0], [2e7, 1e7, 5e6]
    total = cm.total_comm_time(base, batches, bands, 7)
    for b, bw in zip(batches, bands):
        assert total >= 7 * cm.client_comm_time(base, b, bw) - 1e-12
    # Eq. 22 volume scales linearly in the summed batch sizes
    v1 = cm.round_volume_bytes(base, {0: [8.0]}, n_edges=1)
    v2 = cm.round_volume_bytes(base, {0: [16.0]}, n_edges=1)
    assert abs((v2 - base.lora_bytes) - 2 * (v1 - base.lora_bytes)) < 1e-6


def test_comm_config_from_derives_real_shapes():
    import numpy as np

    from repro.configs import get_config
    from repro.core.sketch import make_plan
    from repro.federation.simulation import FedConfig
    from repro.models.bert import bert_specs
    from repro.models.params import init_tree
    import jax

    cfg = get_config("bert-base").reduced().with_(
        num_layers=4, param_dtype="float32", activation_dtype="float32")
    fed = FedConfig(n_clients=4, t_rounds=3, seq_len=48, num_classes=4)
    plan = make_plan(cfg.d_model, 3, 20, seed=0)

    cc = cm.comm_config_from(cfg, fed, plan)
    assert cc.d_hidden == cfg.d_model
    assert cc.seq_len == 48 and cc.t_rounds == 3
    assert cc.bytes_per_param == 4.0
    assert abs(cc.rho - cfg.d_model / (3 * 20)) < 1e-9
    # lora_bytes from the spec tree == bytes of the materialized tree
    tree = init_tree(bert_specs(cfg, 4)["lora"], jax.random.PRNGKey(0))
    manual = sum(l.size * l.dtype.itemsize
                 for l in jax.tree_util.tree_leaves(tree))
    assert cc.lora_bytes == manual
    assert cm.lora_tree_bytes(tree) == manual
    # no plan -> uncompressed (rho = 1)
    assert cm.comm_config_from(cfg, fed, None).rho == 1.0
    # per-dtype zeta: bf16 halves the activation bytes
    cfg16 = cfg.with_(activation_dtype="bfloat16")
    assert cm.comm_config_from(cfg16, fed, plan).bytes_per_param == 2.0
