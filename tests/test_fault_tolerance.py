"""Fault injection + trust-gated update screening (docs/robustness.md).

Three layers of coverage:

1. Pure-core units: the seeded ``FaultTrace`` schedule, the corruption
   operators, the coordinate-wise trimmed mean, and the trust EMA.
2. Screening semantics on synthetic cohorts: each verdict
   (nonfinite/norm/flip/low-trust) fires on the update built to trigger
   it — including the sign-flip Byzantine update, whose delta *norm* is
   indistinguishable from honest and only the direction screen catches.
3. The acceptance gate: with >= 15% of clients shipping corrupted
   updates on every dispatch, screened aggregation stays within 0.05
   final accuracy of the fault-free baseline while the unscreened run
   degrades strictly more (ISSUE acceptance; the committed
   BENCH_fault_tolerance.json pins the same contrast for CI).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core.screening import (FLIP, LOW_TRUST, NONFINITE, NORM, OK,
                                  ScreeningConfig, TrustLedger,
                                  screen_and_aggregate, screen_updates)
from repro.federation.simulation import FedConfig, Federation
from repro.federation.topology import (CORRUPT_MODES, FAULT_KINDS, Fault,
                                       FaultTrace, corrupt_update,
                                       make_fault_trace)
from repro.runtime import RuntimeConfig

# ---------------------------------------------------------------------------
# fault schedule
# ---------------------------------------------------------------------------


def test_fault_trace_deterministic_and_stateless():
    tr = FaultTrace(n_clients=8, crash_rate=0.2, drop_rate=0.1,
                    dup_rate=0.1, corrupt_rate=0.2, seed=7)

    def key(f):
        return None if f is None else (f.kind, f.mode, f.at_frac)

    # stateless: same (client, dispatch) -> same draw, any call order
    a = [key(tr.sample(n, d)) for n in range(8) for d in range(20)]
    rev = {(n, d): key(tr.sample(n, d))
           for d in reversed(range(20)) for n in reversed(range(8))}
    assert a == [rev[(n, d)] for n in range(8) for d in range(20)]
    # and an identically-seeded trace reproduces the schedule
    tr2 = FaultTrace(n_clients=8, crash_rate=0.2, drop_rate=0.1,
                     dup_rate=0.1, corrupt_rate=0.2, seed=7)
    assert a == [key(tr2.sample(n, d))
                 for n in range(8) for d in range(20)]
    kinds = [k[0] for k in a if k is not None]
    assert set(kinds) <= set(FAULT_KINDS)
    # rough rate sanity over 160 draws at 60% total fault probability
    assert 0.3 <= len(kinds) / len(a) <= 0.9


def test_fault_trace_respects_faulty_subset_and_rates():
    tr = make_fault_trace(10, faulty_frac=0.3, corrupt_rate=1.0, seed=1)
    assert len(tr.faulty) == 3
    for n in range(10):
        hits = [tr.sample(n, d) for d in range(5)]
        if n in tr.faulty:
            assert all(f is not None and f.kind == "corrupt" for f in hits)
            assert all(f.mode in CORRUPT_MODES for f in hits)
        else:
            assert all(f is None for f in hits)
    with pytest.raises(ValueError):
        FaultTrace(n_clients=4, crash_rate=0.8, corrupt_rate=0.4)
    with pytest.raises(ValueError):
        FaultTrace(n_clients=4, corrupt_rate=0.1, corrupt_modes=("bogus",))


def test_corrupt_update_semantics():
    base = {"w": jnp.ones((3, 2), jnp.float32)}
    upd = {"w": jnp.full((3, 2), 3.0, jnp.float32)}
    out = corrupt_update(base, upd, Fault("corrupt", mode="nan"))
    assert np.isnan(np.asarray(out["w"])).all()
    out = corrupt_update(base, upd, Fault("corrupt", mode="inf"))
    assert np.isinf(np.asarray(out["w"])).all()
    # signflip mirrors the delta through the base: delta 2 -> -2
    out = corrupt_update(base, upd, Fault("corrupt", mode="signflip"))
    np.testing.assert_allclose(np.asarray(out["w"]), -1.0)
    # scale stretches the delta: 1 + 10*2 = 21
    out = corrupt_update(base, upd, Fault("corrupt", mode="scale",
                                          scale=10.0))
    np.testing.assert_allclose(np.asarray(out["w"]), 21.0)
    assert out["w"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# trimmed mean + trust ledger
# ---------------------------------------------------------------------------


def test_trimmed_mean_resists_outliers():
    trees = [{"w": jnp.full((2,), v, jnp.float32)}
             for v in (1.0, 2.0, 3.0, 1000.0)]
    out = agg.trimmed_mean(trees, trim_frac=0.25)
    np.testing.assert_allclose(np.asarray(out["w"]), 2.5)  # mean of {2,3}
    # one tree: trimming is a no-op mean
    solo = agg.trimmed_mean(trees[:1], trim_frac=0.25)
    np.testing.assert_allclose(np.asarray(solo["w"]), 1.0)
    with pytest.raises(ValueError):
        agg.trimmed_mean([], trim_frac=0.25)
    with pytest.raises(ValueError):
        agg.trimmed_mean(trees, trim_frac=0.5)


def test_trust_ledger_ema_and_state_roundtrip():
    led = TrustLedger(3, beta=0.5)
    led.seed(np.array([1.0, 0.5, 0.0]))      # 0.0 clipped to 1e-6
    assert led.scores[2] == pytest.approx(1e-6)
    led.record(0, False)
    assert led.scores[0] == pytest.approx(0.5)
    led.record(0, True)
    assert led.scores[0] == pytest.approx(0.75)
    assert led.passes[0] == 1 and led.fails[0] == 1
    led2 = TrustLedger(3)
    led2.load_state(led.state())
    np.testing.assert_array_equal(led2.scores, led.scores)
    np.testing.assert_array_equal(led2.passes, led.passes)
    with pytest.raises(ValueError):
        TrustLedger(3, beta=1.5)


# ---------------------------------------------------------------------------
# screening semantics (synthetic stats, no model)
# ---------------------------------------------------------------------------

def _np_stats(base, trees, weights):
    """Reference implementation of the screen statistics in numpy."""
    deltas = [np.asarray(t["w"], np.float64) - np.asarray(base["w"],
                                                          np.float64)
              for t in trees]
    fin = np.array([np.isfinite(d).all() for d in deltas])
    norms = np.array([np.sqrt((d * d).sum()) if f else np.inf
                      for d, f in zip(deltas, fin)])
    w = np.asarray(weights, np.float64) * fin
    mean = sum(wi * np.where(np.isfinite(d), d, 0.0)
               for wi, d in zip(w, deltas)) / max(w.sum(), 1e-12)
    cos = np.array([
        (d * mean).sum() / max(norms[i] * np.sqrt((mean * mean).sum()),
                               1e-12)
        if fin[i] else 0.0 for i, d in enumerate(deltas)])
    return fin, norms, cos


def _tree(v):
    return {"w": jnp.asarray(np.full(8, v, np.float32))}


def test_screen_updates_verdicts_cover_every_failure_mode():
    base = _tree(0.0)
    honest = [_tree(1.0), _tree(1.1), _tree(0.9)]
    bad_nan = {"w": jnp.full(8, jnp.nan)}
    bad_norm = _tree(50.0)                    # >> norm_k * median
    bad_flip = _tree(-1.0)                    # honest norm, cos == -1
    trees = honest + [bad_nan, bad_norm, bad_flip]
    led = TrustLedger(6)
    rep = screen_updates(base, trees, [1.0] * 6, list(range(6)), led,
                         ScreeningConfig(), stats_fn=_np_stats)
    assert rep.verdicts == [OK, OK, OK, NONFINITE, NORM, FLIP]
    assert rep.kept == [0, 1, 2]
    assert rep.n_excluded == 3
    # trust moved toward 0 for the screened-out, toward 1 for the honest
    assert (led.scores[3:] < 1.0).all() and (led.scores[:3] == 1.0).all()


def test_screen_updates_low_trust_exclusion_is_post_update():
    base, led = _tree(0.0), TrustLedger(2, beta=0.5)
    led.scores[:] = [1.0, 0.2]               # client 1 one fail from floor
    rep = screen_updates(base, [_tree(1.0), _tree(1.0)], [1.0, 1.0],
                         [0, 1], led, ScreeningConfig(trust_floor=0.15),
                         stats_fn=_np_stats)
    # client 1 passes the per-round checks (score EMA rises to 0.6) and
    # stays; shrink the floor history further and it would drop
    assert rep.verdicts == [OK, OK]
    led.scores[1] = 0.05                     # deep distrust: even a pass
    rep = screen_updates(base, [_tree(1.0), _tree(1.0)], [1.0, 1.0],
                         [0, 1], led, ScreeningConfig(trust_floor=0.6),
                         stats_fn=_np_stats)  # EMA 0.525 < floor 0.6
    assert rep.verdicts == [OK, LOW_TRUST]
    assert rep.kept == [0]


def test_screen_and_aggregate_fallbacks():
    base = _tree(0.0)
    cfg = ScreeningConfig(min_cohort=2)
    # all nonfinite -> keep the base model untouched
    led = TrustLedger(2)
    out, rep = screen_and_aggregate(
        base, [{"w": jnp.full(8, jnp.nan)}] * 2, [1.0, 1.0], [0, 1],
        led, cfg, mode="factor", stats_fn=_np_stats)
    assert rep.fallback == "keep-base"
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(base["w"]))
    # too few survivors (< min_cohort) -> trimmed mean over the finite
    # updates, NOT a fragile two-client mean
    led = TrustLedger(5)
    trees = [_tree(1.0), _tree(1.2), {"w": jnp.full(8, jnp.nan)},
             _tree(60.0), _tree(-1.0)]
    out, rep = screen_and_aggregate(
        base, trees, [1.0] * 5, [0, 1, 2, 3, 4], led,
        ScreeningConfig(min_cohort=3), mode="factor", stats_fn=_np_stats)
    assert rep.fallback == "trimmed"
    # finite updates sort to [-1, 1, 1.2, 60]; one trimmed per side
    np.testing.assert_allclose(np.asarray(out["w"]), 1.1, rtol=1e-6)
    # healthy cohort -> plain trust-weighted aggregation, no fallback
    led = TrustLedger(3)
    out, rep = screen_and_aggregate(base, [_tree(1.0)] * 3, [1.0] * 3,
                                    [0, 1, 2], led, cfg, mode="factor",
                                    stats_fn=_np_stats)
    assert rep.fallback == "" and rep.kept == [0, 1, 2]
    np.testing.assert_allclose(np.asarray(out["w"]), 1.0, rtol=1e-6)


def test_engine_screen_stats_matches_reference():
    from repro.federation.engine import screen_stats
    base = {"a": jnp.asarray(np.arange(6, dtype=np.float32).reshape(2, 3)),
            "b": jnp.zeros(4, jnp.float32)}
    rng = np.random.default_rng(0)

    def perturb(scale, flip=False, nan=False):
        out = {}
        for k, v in base.items():
            d = scale * rng.standard_normal(v.shape).astype(np.float32)
            out[k] = jnp.asarray(np.asarray(v) + (-d if flip else d))
        if nan:
            out["a"] = out["a"].at[0, 0].set(jnp.nan)
        return out

    trees = [perturb(0.1), perturb(0.1), perturb(5.0), perturb(0.1,
                                                              nan=True)]
    fin, norms, cos = screen_stats(base, trees, [1.0] * 4)
    assert fin.tolist() == [True, True, True, False]
    assert norms[2] > 10 * max(norms[0], norms[1])
    # a sign-flipped copy of an honest update scores cosine ~ -1 against
    # a cohort mean dominated by honest mass
    honest = perturb(0.1)
    flipped = {k: jnp.asarray(2 * np.asarray(base[k]) - np.asarray(v))
               for k, v in honest.items()}
    fin, norms, cos = screen_stats(base, [honest, honest, flipped],
                                   [1.0, 1.0, 1.0])
    assert np.isclose(norms[2], norms[0], rtol=0.5)  # norm screen blind
    assert cos[2] < -0.5 < cos[0]                    # direction screen not


# ---------------------------------------------------------------------------
# acceptance: screened federation survives Byzantine corruption
# ---------------------------------------------------------------------------

GATE = dict(n_clients=4, n_edges=2, alpha=5.0, poisoned=(),
            total_examples=800, probe_q=8, local_warmup_steps=2,
            layers=4, t_rounds=1, batch_size=16, seed=0, seq_len=32,
            class_sharpness=10.0, background_frac=0.0, num_classes=4,
            use_channel=False, clip_norm=1.0, lr=5e-3, head_lr=0.4,
            pooling="mean", server_opt="fedadam", server_lr=0.03)
ROUNDS, STEPS = 14, 6


def _final_acc(screen: bool, faults) -> float:
    fed = Federation(FedConfig(**GATE, screen=screen), backend="batched")
    h = fed.run("elsa", global_rounds=ROUNDS, steps_per_round=STEPS,
                runtime=RuntimeConfig(policy="sync", faults=faults))
    return h["final_accuracy"]


def test_screened_aggregation_survives_corrupted_clients():
    """>= 15% of clients (1 of 4) ship corrupted updates on EVERY
    dispatch.  Screened: within 0.05 of the fault-free run.  Unscreened:
    strictly worse degradation (NaNs propagate straight into theta)."""
    faults = make_fault_trace(GATE["n_clients"], faulty_frac=0.25,
                              corrupt_rate=1.0, corrupt_modes=("nan",),
                              seed=11)
    assert len(faults.faulty) / GATE["n_clients"] >= 0.15
    clean = _final_acc(False, None)
    screened = _final_acc(True, faults)
    unscreened = _final_acc(False, faults)
    assert screened >= clean - 0.05, \
        f"screened {screened:.3f} fell > 0.05 below fault-free {clean:.3f}"
    assert (clean - unscreened) > (clean - screened), \
        (f"unscreened {unscreened:.3f} should degrade more than "
         f"screened {screened:.3f} (fault-free {clean:.3f})")


def test_screening_off_is_bit_inert():
    """screen=False issues the identical aggregation call: histories of
    a default run and an explicit screen=False run match bit-for-bit
    (the golden-pinned parity files cover the default path itself)."""
    kw = dict(GATE, total_examples=200, seq_len=16)
    h1 = Federation(FedConfig(**kw)).run("elsa", global_rounds=2,
                                         steps_per_round=2)
    h2 = Federation(FedConfig(**kw, screen=False)).run(
        "elsa", global_rounds=2, steps_per_round=2)
    assert h1["accuracy"] == h2["accuracy"]
    assert h1["loss"] == h2["loss"]
    assert h1["delta"] == h2["delta"]
