"""Data pipeline, checkpointing, comm-model, and HLO-parser substrates."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_cost import analyze
from repro.checkpoint import restore, save
from repro.data.pipeline import batch_iterator
from repro.data.probe import make_probe_set
from repro.data.synthetic import (SyntheticTaskConfig, dirichlet_partition,
                                  make_federation_data, make_task,
                                  make_test_set, poison_labels,
                                  quantity_skew)


def test_dirichlet_partition_properties():
    props = dirichlet_partition(10, 4, alpha=0.1, seed=0)
    assert props.shape == (10, 4)
    np.testing.assert_allclose(props.sum(1), 1.0, atol=1e-9)
    # low alpha -> skewed: most clients dominated by one class
    assert (props.max(1) > 0.6).mean() > 0.5


def test_quantity_skew_monotone():
    sizes = quantity_skew(8, 1000)
    assert (np.diff(sizes) >= 0).all()
    assert sizes.sum() <= 1100


def test_poisoning_changes_labels():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 4, 200)
    poisoned = poison_labels(labels, 0.5, 4, rng)
    assert 0.25 < (poisoned != labels).mean() < 0.55


def test_task_is_learnable_classes_distinct():
    cfg = SyntheticTaskConfig(vocab_size=256, num_classes=4, seq_len=16)
    p = make_task(cfg)
    # class distributions concentrate on distinct segments
    for c in range(4):
        seg = slice(c * 64, (c + 1) * 64)
        assert p[c, seg].sum() > 0.5


def test_federation_data_end_to_end():
    cfg = SyntheticTaskConfig(vocab_size=128, num_classes=4, seq_len=12)
    data = make_federation_data(cfg, 6, 600, alpha=0.2,
                                poisoned_clients=(1,))
    assert set(data) == set(range(6))
    assert data[1].poisoned and not data[0].poisoned
    toks, labels = make_test_set(cfg, 64)
    assert toks.shape == (64, 12) and labels.shape == (64,)
    assert toks.max() < 128


def test_batch_iterator_covers_epoch():
    toks = np.arange(50)[:, None].repeat(3, 1)
    labels = np.arange(50) % 2
    seen = []
    for bt, bl in batch_iterator(toks, labels, 16, seed=1):
        seen.extend(bt[:, 0].tolist())
    assert sorted(seen) == list(range(50))


def test_probe_set_shapes():
    cfg = SyntheticTaskConfig(vocab_size=128, num_classes=4, seq_len=12)
    probe = make_probe_set(cfg, 20)
    assert probe.shape == (20, 12) and probe.max() < 128


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2], jnp.int32),
                  "d": [jnp.ones((2,), jnp.bfloat16)]}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.msgpack")
        save(path, tree)
        back = restore(path)
    np.testing.assert_array_equal(np.asarray(back["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(back["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))
    assert back["b"]["d"][0].dtype == jnp.bfloat16


def test_hlo_parser_matches_xla_on_unrolled():
    def f(x, w):
        for i in range(4):
            x = jnp.tanh(x @ w[i])
        return x.sum()
    c = jax.jit(f).lower(jnp.zeros((64, 128)),
                         jnp.zeros((4, 128, 128))).compile()
    parsed = analyze(c.as_text())
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):    # older jax returns [dict]
        ca = ca[0]
    xla = ca["flops"]
    assert abs(parsed.flops - xla) / xla < 0.05


def test_hlo_parser_multiplies_scan_trips():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0].sum()
    c = jax.jit(f).lower(jnp.zeros((64, 128)),
                         jnp.zeros((10, 128, 128))).compile()
    parsed = analyze(c.as_text())
    one_body = 2 * 64 * 128 * 128
    assert parsed.flops > 9 * one_body   # ~10x the single-body flops
