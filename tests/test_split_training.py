"""Tripartite split training invariants (§III.B.2–3)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.sketch import make_plan
from repro.core.split_training import (Channel, IDENTITY_CHANNEL, Split,
                                       split_forward, split_loss)
from repro.core.ssop import make_ssop
from repro.models import bert as bert_mod
from repro.models.params import init_tree

CFG = get_config("bert-base").reduced().with_(num_layers=6)


def _setup():
    tree = init_tree(bert_mod.bert_specs(CFG, 4), jax.random.PRNGKey(0),
                     jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                              CFG.vocab_size)
    labels = jnp.array([0, 1, 2, 3])
    return tree["frozen"], tree["lora"], toks, labels


def test_split_equals_full_forward_without_channel():
    frozen, lora, toks, _ = _setup()
    _, full_cls, full_logits = bert_mod.bert_forward(CFG, frozen, lora, toks)
    for split in [Split(1, 3, 2), Split(2, 2, 2), Split(3, 1, 2)]:
        cls, logits, _, _ = split_forward(CFG, frozen, lora, toks, split,
                                          IDENTITY_CHANNEL)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits), atol=1e-5)


def test_ssop_only_channel_is_exact():
    """SS-OP without sketching is a perfect (rotate, un-rotate) channel."""
    frozen, lora, toks, labels = _setup()
    emb = jax.random.normal(jax.random.PRNGKey(3), (32, CFG.d_model))
    ch = Channel(make_ssop(emb, 4, "salt", 0), None)
    split = Split(2, 2, 2)
    batch = {"tokens": toks, "labels": labels}
    l_id = float(split_loss(CFG, frozen, lora, batch, split,
                            IDENTITY_CHANNEL))
    l_ch = float(split_loss(CFG, frozen, lora, batch, split, ch))
    # exact in exact arithmetic; the fp32 QR/SVD orthogonality error
    # (~1e-6) is amplified ~100x through the remaining encoder stack
    assert abs(l_id - l_ch) < 5e-4


def test_exact_gradient_restoration_through_ssop():
    """Backward through the orthogonal channel == backward without it
    (paper's 'training remains stable' property)."""
    frozen, lora, toks, labels = _setup()
    emb = jax.random.normal(jax.random.PRNGKey(3), (32, CFG.d_model))
    ch = Channel(make_ssop(emb, 4, "salt", 0), None)
    split = Split(2, 2, 2)
    batch = {"tokens": toks, "labels": labels}
    g_id = jax.grad(lambda lp: split_loss(CFG, frozen, lp, batch, split,
                                          IDENTITY_CHANNEL))(lora)
    g_ch = jax.grad(lambda lp: split_loss(CFG, frozen, lp, batch, split,
                                          ch))(lora)
    # exact in exact arithmetic; fp32 rotation noise amplifies through the
    # stack, so compare relative to each leaf's gradient scale
    for a, b in zip(jax.tree_util.tree_leaves(g_id),
                    jax.tree_util.tree_leaves(g_ch)):
        scale = max(float(jnp.abs(a).max()), 1e-3)
        assert float(jnp.abs(a - b).max()) / scale < 2e-2


def test_lossy_channel_still_trains():
    frozen, lora, toks, labels = _setup()
    emb = jax.random.normal(jax.random.PRNGKey(3), (32, CFG.d_model))
    plan = make_plan(CFG.d_model, 3, CFG.d_model // 2, seed=2)
    ch = Channel(make_ssop(emb, 4, "salt", 0), plan)
    split = Split(2, 2, 2)
    batch = {"tokens": toks, "labels": labels}
    g_fn = jax.jit(jax.value_and_grad(
        lambda lp: split_loss(CFG, frozen, lp, batch, split, ch)))
    losses = []
    lora2 = lora
    # lossy channel -> noisy steps: at lr 1e-2 the 8-step trajectory
    # merely hovers (and which side of the start it lands on flips with
    # the container's XLA codegen); at lr 2e-3 over 24 steps the descent
    # is unambiguous (~1.28 -> ~0.68 here), so the assert carries a real
    # margin instead of riding a knife edge
    for _ in range(24):
        lv, g = g_fn(lora2)
        lora2 = jax.tree_util.tree_map(lambda p, gg: p - 0.002 * gg,
                                       lora2, g)
        losses.append(float(lv))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < losses[0] - 0.25


def test_split_train_step_compiled_step_trains():
    """The jitted split_train_step runs end to end and reduces loss;
    the default (donate=False) must leave the caller's input arrays
    reusable."""
    from repro.core.split_training import split_train_step
    from repro.optim import SGD

    frozen, lora, toks, labels = _setup()
    emb = jax.random.normal(jax.random.PRNGKey(3), (32, CFG.d_model))
    plan = make_plan(CFG.d_model, 3, CFG.d_model // 2, seed=2)
    ch = Channel(make_ssop(emb, 4, "salt", 0), plan)
    opt = SGD(lr=2e-2)
    step = split_train_step(CFG, Split(2, 2, 2), ch, opt)
    state = opt.init(lora)
    batch = {"tokens": toks, "labels": labels}
    losses = []
    cur = lora
    for _ in range(6):
        cur, state, lv = step(frozen, cur, state, batch)
        losses.append(float(lv))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-2:]) < losses[0] + 0.02
    # donate=False default: the original input tree is still usable
    _ = float(jax.tree_util.tree_leaves(lora)[0].sum())


def test_transmitted_payload_is_compressed_and_rotated():
    """What crosses the wire has sketch shape, and is NOT the raw hidden."""
    frozen, lora, toks, _ = _setup()
    emb = jax.random.normal(jax.random.PRNGKey(3), (32, CFG.d_model))
    plan = make_plan(CFG.d_model, 3, CFG.d_model // 4, seed=2)
    ch = Channel(make_ssop(emb, 8, "salt", 0), plan)
    _, _, h_up, _ = split_forward(CFG, frozen, lora, toks, Split(2, 2, 2),
                                  IDENTITY_CHANNEL)
    wire = ch.transmit(h_up)
    assert wire.shape == h_up.shape[:-1] + (3, CFG.d_model // 4)
    # rho = D / (Y Z) > 1 => fewer floats on the wire
    assert wire.size < h_up.size
