"""Per-architecture smoke tests: reduced variant of every assigned arch,
one forward / train step on CPU, output shapes + no NaNs, and
prefill/decode equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import zoo
from repro.models.params import init_tree, count_params
from repro.optim import AdamW

DECODELESS = {"encoder"}


def _batch(cfg, key, b=2, s=16):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            jax.random.PRNGKey(7), (b, cfg.num_vision_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(7), (b, cfg.num_audio_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 8 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    model = zoo.get_model(cfg)
    params = init_tree(model.specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, aux = model.forward(cfg, params["frozen"], params["lora"],
                                batch, remat=False)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())

    # one LoRA train step: loss finite and decreases over 3 steps
    opt = AdamW(lr=5e-3)
    state = opt.init(params["lora"])
    lora = params["lora"]

    def loss_fn(lp):
        lg, aux_ = model.forward(cfg, params["frozen"], lp, batch,
                                 remat=False)
        return zoo.loss_fn(cfg, lg, batch["tokens"], aux_)

    losses = []
    for _ in range(3):
        loss, g = jax.value_and_grad(loss_fn)(lora)
        lora, state = opt.update(lora, g, state)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] + 1e-3


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if get_config(a).family not in DECODELESS])
def test_decode_matches_prefill(arch):
    cfg = get_config(arch).reduced()
    model = zoo.get_model(cfg)
    params = init_tree(model.specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, _ = model.forward(cfg, params["frozen"], params["lora"], batch,
                              remat=False)

    cache = init_tree(model.cache_specs(cfg, 2, 16), jax.random.PRNGKey(2),
                      jnp.float32)
    if cfg.family == "audio":
        from repro.models import whisper as wm
        cache = wm.whisper_prefill_cache(cfg, params["frozen"],
                                         params["lora"], batch["frames"],
                                         2, 16)
    if cfg.family == "vlm":
        from repro.models import common as cm
        ls = cfg.lora.alpha / cfg.lora.rank
        def per(p, lp):
            ck = cm.project(p["cross"]["attn"], lp["cross"]["attn"],
                            batch["vision"], "k", ls)
            cv = cm.project(p["cross"]["attn"], lp["cross"]["attn"],
                            batch["vision"], "v", ls)
            return ck, cv
        cks, cvs = jax.vmap(per)(params["frozen"]["periods"],
                                 params["lora"]["periods"])
        cache["periods"]["cross"]["ck"] = cks
        cache["periods"]["cross"]["cv"] = cvs

    outs = []
    c = cache
    for t in range(6):
        lg, c = model.decode_step(cfg, params["frozen"], params["lora"], c,
                                  {"tokens": batch["tokens"][:, t:t + 1]})
        outs.append(lg[:, 0])
    dec = np.asarray(jnp.stack(outs, 1))
    ref = np.asarray(logits[:, :6])
    np.testing.assert_allclose(dec, ref, atol=5e-4, rtol=5e-3)


def test_param_counts_full_configs():
    """Full (non-reduced) configs should land near their nameplate sizes."""
    expect = {"llama3-8b": (7.0e9, 9.0e9),
              "grok-1-314b": (2.8e11, 3.4e11),
              "deepseek-v2-236b": (2.0e11, 2.6e11),
              "jamba-v0.1-52b": (4.3e10, 5.8e10),
              # our mLSTM uses full (d_inner x d_inner) q/k/v projections
              # (DESIGN.md §4 note); block-diagonal per-head would land at
              # the 1.3B nameplate
              "xlstm-1.3b": (1.0e9, 4.0e9),
              "olmo-1b": (0.9e9, 1.5e9)}
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        n = count_params(zoo.get_model(cfg).specs(cfg)["frozen"])
        assert lo < n < hi, f"{arch}: {n:.3e} outside [{lo:.1e}, {hi:.1e}]"
