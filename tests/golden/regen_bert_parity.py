"""Re-record the BERT run-level parity goldens in the current
environment.

Run after an *intentional* container/toolchain upgrade (never to paper
over an unexplained mismatch in an unchanged environment — that is the
regression the goldens exist to catch)::

    PYTHONPATH=src python tests/golden/regen_bert_parity.py

Writes ``bert_parity.json`` (legacy factor-averaging aggregation) and
``bert_parity_product.json`` (product-space aggregation), each stamped
with the recording environment's fingerprint (``tests/golden_env.py``):
a matching environment asserts the history at float precision, a
drifted one falls back to tolerance bands.
"""
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(HERE, "..", "..", "src"))
sys.path.insert(0, os.path.join(HERE, ".."))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from golden_env import fingerprint  # noqa: E402
from repro.federation.simulation import FedConfig, Federation  # noqa: E402

BASE = dict(n_clients=6, n_edges=2, alpha=0.2, poisoned=[4],
            total_examples=600, probe_q=8, local_warmup_steps=2,
            lr=0.02, layers=4, t_rounds=1, batch_size=16, seed=0)
RUN = dict(method="elsa", global_rounds=2, steps_per_round=2)


def record(config: dict) -> dict:
    kw = dict(config)
    kw["poisoned"] = tuple(kw["poisoned"])
    fed = Federation(FedConfig(**kw), backend="batched")
    h = fed.run(RUN["method"], global_rounds=RUN["global_rounds"],
                steps_per_round=RUN["steps_per_round"])
    sums = [float(np.asarray(l, np.float64).sum())
            for l in jax.tree_util.tree_leaves(fed.last_theta)]
    return {
        "config": config,
        "run": dict(RUN),
        "env": fingerprint(),
        "loss": [float(x) for x in h["loss"]],
        "accuracy": [float(x) for x in h["accuracy"]],
        "delta": [float(x) for x in h["delta"]],
        "round": [int(r) for r in h["round"]],
        "client_losses": {str(n): [float(x) for x in v]
                          for n, v in h["client_losses"].items()},
        "theta_leaf_sums": sums,
    }


if __name__ == "__main__":
    for aggregate, fname in (("factor", "bert_parity.json"),
                             ("product", "bert_parity_product.json")):
        gold = record({**BASE, "aggregate": aggregate})
        path = os.path.join(HERE, fname)
        with open(path, "w") as f:
            json.dump(gold, f)
        print(f"wrote {path}: loss={gold['loss']} acc={gold['accuracy']}")
