"""Checkpoint wire format + full federation kill-and-resume.

The wire-level tests pin the v2 msgpack format: tuples survive (the v1
``_to_wire`` collapsed them into lists, silently re-typing pytree
treedefs on restore — regression-tested here), every dtype restores
bit-exactly (float64 trust vectors included — decoding through
``jnp.asarray`` would silently downcast under jax's default x64-off
config), and truncation/version-skew/missing-section failures raise
clear ``ValueError``s instead of surfacing as msgpack internals.

The federation tests assert the headline robustness guarantee: a sync
run killed at a round boundary and resumed *in a fresh process* from
its checkpoint finishes with bit-identical history, event trace, and
final theta (docs/robustness.md).
"""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

import repro.checkpoint.federation as fedckpt
from repro.checkpoint import (CheckpointConfig, Checkpointer,
                              latest_checkpoint, restore, restore_state,
                              save, save_state, tree_equal)
from repro.data.pipeline import CountingIterator, infinite_batches
from repro.federation.simulation import FedConfig, Federation
from repro.runtime import RuntimeConfig

SMALL = dict(n_clients=4, n_edges=2, alpha=5.0, poisoned=(),
             total_examples=200, probe_q=8, local_warmup_steps=1,
             layers=4, t_rounds=1, batch_size=8, seed=0, seq_len=16,
             num_classes=4, use_channel=True, clip_norm=1.0)


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------

def test_tuples_survive_roundtrip(tmp_path):
    """Regression: v1 ``_to_wire`` collapsed tuples into lists, so a
    restored pytree had a different treedef than the saved one (trace
    records and optimizer states carry tuples)."""
    p = str(tmp_path / "t.msgpack")
    obj = {"rec": (1.5, "arrival", 3, (("late", 0), ("round", 2))),
           "nest": [(1, 2), [3, (4,)]], "empty": ()}
    save(p, obj)
    out = restore(p)
    assert out == obj
    assert isinstance(out["rec"], tuple)
    assert isinstance(out["rec"][3][0], tuple)
    assert isinstance(out["nest"][0], tuple) and out["empty"] == ()
    assert isinstance(out["nest"][1], list)


def test_every_dtype_restores_bit_exactly(tmp_path):
    p = str(tmp_path / "d.msgpack")
    rng = np.random.default_rng(0)
    tree = {
        "f32": jnp.asarray(rng.standard_normal((3, 4)), jnp.float32),
        "f64": rng.standard_normal(5),                   # numpy float64
        "i32": jnp.arange(6, dtype=jnp.int32),
        "i64": np.arange(4, dtype=np.int64) * 10**12,
        "bool": np.array([True, False, True]),
        "bf16": jnp.asarray([1.5, -2.25, 3.0], jnp.bfloat16),
        "scalar": 3.25, "none": None, "s": "theta", "flag": True,
    }
    save(p, tree)
    out = restore(p)
    assert tree_equal(tree, out)
    assert out["f64"].dtype == np.float64         # NOT downcast to f32
    assert out["i64"].dtype == np.int64
    assert out["bf16"].dtype == ml_dtypes.bfloat16
    assert out["scalar"] == 3.25 and out["none"] is None


def test_object_dtype_rejected(tmp_path):
    with pytest.raises(TypeError, match="object-dtype"):
        save(str(tmp_path / "o.msgpack"), {"bad": np.array([{}, {}])})


def test_save_is_atomic_no_partial_file(tmp_path):
    p = str(tmp_path / "sub" / "a.msgpack")
    os.makedirs(os.path.dirname(p))
    with pytest.raises(TypeError):
        save(p, {"bad": object()})
    assert os.listdir(os.path.dirname(p)) == []   # no temp/partial left


def test_restore_state_validation_errors(tmp_path):
    params = {"w": jnp.ones((2, 2), jnp.float32)}
    p = str(tmp_path / "s.msgpack")
    save_state(p, params=params, opt_state=None, step=3)
    out = restore_state(p)
    assert out["step"] == 3 and out["opt_state"] is None
    assert tree_equal(out["params"], params)

    # truncation -> "corrupt or truncated", not a msgpack internal
    raw = open(p, "rb").read()
    t = str(tmp_path / "trunc.msgpack")
    open(t, "wb").write(raw[:len(raw) // 2])
    with pytest.raises(ValueError, match="corrupt or truncated"):
        restore_state(t)

    # a non-state payload -> missing format marker
    q = str(tmp_path / "not_state.msgpack")
    save(q, {"just": "data"})
    with pytest.raises(ValueError, match="format"):
        restore_state(q)

    # version skew -> explicit version error
    state = restore(p)
    state["__version__"] = 99
    v = str(tmp_path / "vers.msgpack")
    save(v, state)
    with pytest.raises(ValueError, match="version"):
        restore_state(v)

    # missing section
    state = restore(p)
    del state["params"]
    m = str(tmp_path / "miss.msgpack")
    save(m, state)
    with pytest.raises(ValueError, match="params"):
        restore_state(m)


def test_counting_iterator_fast_forward():
    def stream():
        return infinite_batches(np.arange(40).reshape(10, 4),
                                np.arange(10), 2, seed=3)
    a = CountingIterator(stream())
    for _ in range(7):
        next(a)
    b = CountingIterator(stream())
    b.fast_forward(7)
    assert a.count == b.count == 7
    (ta, la), (tb, lb) = next(a), next(b)
    np.testing.assert_array_equal(ta, tb)
    np.testing.assert_array_equal(la, lb)
    with pytest.raises(ValueError):
        b.fast_forward(2)       # cannot rewind a forward-only stream


# ---------------------------------------------------------------------------
# rolling federation checkpoints
# ---------------------------------------------------------------------------

def test_checkpointer_rolls_and_prunes(tmp_path):
    d = str(tmp_path)
    ck = Checkpointer(CheckpointConfig(dir=d, every=2, keep=2))
    assert ck.due(0, 9, 1.0, 0.0) and not ck.due(1, 9, 1.0, 0.0)
    assert ck.due(9, 9, 1.0, 0.0)          # final round always snapshots
    assert ck.due(3, 9, 0.0, 0.1)          # convergence stop too
    for g in (0, 2, 4, 6):
        ck.save(g, {"__format__": fedckpt.FORMAT,
                    "__version__": fedckpt.VERSION, "round": g})
    names = sorted(os.listdir(d))
    assert names == ["ckpt_round_000004.msgpack",
                     "ckpt_round_000006.msgpack"]
    assert latest_checkpoint(d).endswith("000006.msgpack")
    with pytest.raises(ValueError):
        CheckpointConfig(dir=d, every=0)


def test_load_state_rejects_foreign_and_skewed(tmp_path):
    p = str(tmp_path / "x.msgpack")
    save(p, {"no": "marker"})
    with pytest.raises(ValueError, match="format marker"):
        fedckpt.load_state(p)
    save(p, {"__format__": "other-tool", "__version__": 1})
    with pytest.raises(ValueError, match="other-tool"):
        fedckpt.load_state(p)
    save(p, {"__format__": fedckpt.FORMAT, "__version__": 99})
    with pytest.raises(ValueError, match="version"):
        fedckpt.load_state(p)
    save(p, {"__format__": fedckpt.FORMAT,
             "__version__": fedckpt.VERSION, "round": 0})
    with pytest.raises(ValueError, match="missing sections"):
        fedckpt.load_state(p)
    with pytest.raises(ValueError, match="no federation checkpoints"):
        fedckpt.resolve(str(tmp_path))


# ---------------------------------------------------------------------------
# resume = bit-identical continuation
# ---------------------------------------------------------------------------

def _run(fed_kw, *, runtime=None, **run_kw):
    fed = Federation(FedConfig(**fed_kw))
    h = fed.run("elsa", global_rounds=3, steps_per_round=2,
                eval_every=1, runtime=runtime, **run_kw)
    return fed, h


def test_plain_loop_resume_is_bit_identical(tmp_path):
    d = str(tmp_path / "ck")
    fedA, hA = _run(SMALL, checkpoint=CheckpointConfig(dir=d, keep=9))
    fedB, hB = _run(SMALL, resume_from=fedckpt.round_path(d, 0))
    assert hA["accuracy"] == hB["accuracy"]
    assert hA["loss"] == hB["loss"] and hA["delta"] == hB["delta"]
    assert tree_equal(fedA.last_theta, fedB.last_theta)


def test_sync_runtime_resume_matches_history_and_trace(tmp_path):
    d = str(tmp_path / "ck")
    rt = RuntimeConfig(policy="sync")
    fedA, hA = _run(SMALL, runtime=rt,
                    checkpoint=CheckpointConfig(dir=d, keep=9))
    fedB, hB = _run(SMALL, runtime=RuntimeConfig(policy="sync"),
                    resume_from=fedckpt.round_path(d, 1))
    assert hA["accuracy"] == hB["accuracy"]
    assert hA["time"] == hB["time"]
    assert hA["trace"].records == hB["trace"].records
    assert tree_equal(fedA.last_theta, fedB.last_theta)
    # resuming a finished run is a no-op returning the final state
    fedC, hC = _run(SMALL, runtime=RuntimeConfig(policy="sync"),
                    resume_from=d)
    assert hC["accuracy"] == hA["accuracy"]
    assert tree_equal(fedC.last_theta, fedA.last_theta)


def test_resume_rejects_config_and_method_drift(tmp_path):
    d = str(tmp_path / "ck")
    _run(SMALL, checkpoint=CheckpointConfig(dir=d, keep=9))
    with pytest.raises(ValueError, match="config mismatch"):
        _run(dict(SMALL, lr=0.123), resume_from=fedckpt.round_path(d, 0))
    fed = Federation(FedConfig(**SMALL))
    with pytest.raises(ValueError, match="method"):
        fed.run("fedavg", global_rounds=3, steps_per_round=2,
                resume_from=fedckpt.round_path(d, 0))
    with pytest.raises(ValueError, match="sync"):
        fed.run("elsa", global_rounds=3,
                runtime=RuntimeConfig(policy="deadline"),
                checkpoint=CheckpointConfig(dir=d))


_RESUME_CHILD = """
import json, sys
from repro.federation.simulation import FedConfig, Federation
from repro.runtime import RuntimeConfig
from repro.checkpoint.checkpoint import save

ckpt_path, out_path, kw_json = sys.argv[1], sys.argv[2], sys.argv[3]
kw = json.loads(kw_json)
kw["poisoned"] = tuple(kw["poisoned"])   # json has no tuples
fed = Federation(FedConfig(**kw))
h = fed.run("elsa", global_rounds=3, steps_per_round=2, eval_every=1,
            runtime=RuntimeConfig(policy="sync"), resume_from=ckpt_path)
save(out_path, {"accuracy": h["accuracy"], "time": h["time"],
                "loss": h["loss"], "trace": h["trace"].records,
                "theta": fed.last_theta})
"""


def test_kill_and_resume_in_fresh_process(tmp_path):
    """The headline guarantee: checkpoint mid-training, resume in a
    FRESH process (nothing shared but the checkpoint file), and the
    final history, event trace, and theta match bit-for-bit."""
    d = str(tmp_path / "ck")
    fedA, hA = _run(SMALL, runtime=RuntimeConfig(policy="sync"),
                    checkpoint=CheckpointConfig(dir=d, keep=9))
    out = str(tmp_path / "resumed.msgpack")
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run(
        [sys.executable, "-c", _RESUME_CHILD,
         fedckpt.round_path(d, 1), out, json.dumps(SMALL)],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    res = restore(out)
    assert res["accuracy"] == hA["accuracy"]
    assert res["time"] == hA["time"]
    assert res["loss"] == hA["loss"]
    assert list(res["trace"]) == hA["trace"].records
    assert tree_equal(res["theta"], fedA.last_theta)
