"""Convergence coverage in two tiers:

1. Theorem 1 sanity on a controlled quadratic testbed: O(1/sqrt(G))
   decay of the average gradient norm plus a non-vanishing non-IID
   floor (sigma_2^2).
2. The tier-1 convergence gate: the tuned stack (product-space adapter
   aggregation + global-norm clipping + per-group lrs + mean-pool
   readout + bias-corrected FedAdam server step) must reach
   above-chance test accuracy (>= chance + 0.15) on the synthetic task
   for BOTH registered model families — the repo's accuracy claims stay
   CI-gated instead of aspirational (docs/convergence.md has the study
   behind these hyperparameters).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import fedavg
from repro.federation.simulation import FedConfig, Federation

# the tuned convergence stack (docs/convergence.md): small federation,
# 4-layer reduced models, valid tripartite split (layers >= 4), mild
# label skew, uncompressed activations (the sketch-channel-on gap is a
# tracked open item, not part of this gate)
CONV_BASE = dict(n_clients=4, n_edges=2, alpha=5.0, poisoned=(),
                 total_examples=800, probe_q=8, local_warmup_steps=2,
                 layers=4, t_rounds=1, batch_size=16, seed=0,
                 seq_len=32, class_sharpness=10.0, background_frac=0.0,
                 num_classes=4, use_channel=False, clip_norm=1.0)

BERT_GATE = dict(CONV_BASE, lr=5e-3, head_lr=0.4, pooling="mean",
                 server_opt="fedadam", server_lr=0.03)
# causal LM: the readout is the frozen vocab projection, so ALL the
# learning happens in the clipped rank-4 adapters — large clipped lr
LM_GATE = dict(CONV_BASE, model="llama3-8b", vocab_size=32, lr=0.5)


def _make_clients(n_clients, d, hetero, seed=0):
    """Quadratic clients F_n(x) = ||x - c_n||^2 with spread ~ hetero,
    shared offset so x0=0 is far from every optimum."""
    rng = np.random.default_rng(seed)
    centers = 3.0 + hetero * rng.standard_normal((n_clients, d))
    return jnp.asarray(centers, jnp.float32)


def _run_fed(centers, g_rounds, local_steps=4, lr=None, sketch_noise=0.0,
             seed=0):
    n, d = centers.shape
    key = jax.random.PRNGKey(seed)
    x = jnp.zeros(d)
    grad_norms = []
    for g in range(g_rounds):
        step = lr if lr else 1.0 / np.sqrt(g_rounds)
        locals_ = []
        for c in range(n):
            xn = x
            for _ in range(local_steps):
                grad = 2 * (xn - centers[c])
                if sketch_noise:
                    key, k2 = jax.random.split(key)
                    grad = grad + sketch_noise * jax.random.normal(k2, (d,))
                xn = xn - step * grad
            locals_.append({"x": xn})
        x = fedavg(locals_, [1.0] * n)["x"]
        global_grad = 2 * (x - centers.mean(0))
        grad_norms.append(float(jnp.sum(global_grad ** 2)))
    return np.asarray(grad_norms)


def test_convergence_rate_scales_with_sqrt_g():
    """With eta = 1/sqrt(G) and persistent gradient noise, the residual
    noise ball scales like eta^2 ~ 1/G (Theorem 1's vanishing
    sigma_local/sqrt(G) term)."""
    centers = _make_clients(8, 16, hetero=0.0)
    short = _run_fed(centers, 16, sketch_noise=0.5)[-4:].mean()
    long = _run_fed(centers, 256, sketch_noise=0.5)[-4:].mean()
    assert long < short * 0.5


def test_noniid_floor_grows_with_heterogeneity():
    """sigma_2^2 term: more heterogeneity -> higher residual."""
    tails = []
    for hetero in (0.1, 2.0):
        centers = _make_clients(8, 16, hetero=hetero, seed=1)
        norms = _run_fed(centers, 128, lr=0.05)
        tails.append(norms[-16:].mean())
    assert tails[1] > tails[0]


def test_sketch_noise_vanishes_with_g():
    """sigma_local^2/sqrt(G): noisy-channel runs still converge, slower."""
    centers = _make_clients(6, 8, hetero=0.0, seed=2)
    clean = _run_fed(centers, 128)
    noisy = _run_fed(centers, 128, sketch_noise=0.5)
    assert noisy[-16:].mean() < noisy[:16].mean()   # still converging
    assert clean[-16:].mean() <= noisy[-16:].mean() + 1e-6


# ---------------------------------------------------------------------------
# tier-1 convergence gate: above-chance accuracy, both model families
# ---------------------------------------------------------------------------

def _chance(fed: Federation) -> float:
    """Chance-level test accuracy for the federation's task."""
    if fed.model.task == "causal-lm":
        return 1.0 / fed.model.cfg.vocab_size
    return 1.0 / fed.fed.num_classes


@pytest.mark.parametrize("name,kw,rounds,steps", [
    ("bert-base", BERT_GATE, 20, 6),
    ("llama3-8b", LM_GATE, 14, 12),
])
def test_tuned_stack_beats_chance(name, kw, rounds, steps):
    """The convergence rescue, pinned: deterministic seed, batched
    backend, final test accuracy >= chance + 0.15 (4-class
    classification: chance 0.25; next-token over the 32-token vocab:
    chance 1/32)."""
    fed = Federation(FedConfig(**kw), backend="batched")
    h = fed.run("elsa", global_rounds=rounds, steps_per_round=steps)
    chance = _chance(fed)
    assert h["final_accuracy"] >= chance + 0.15, \
        (f"{name}: final accuracy {h['final_accuracy']:.3f} below "
         f"chance+0.15 = {chance + 0.15:.3f} "
         f"(history: {[round(a, 3) for a in h['accuracy']]})")
    # and it actually trained (loss moved), not a lucky readout
    assert h["loss"][-1] < h["loss"][0]


def test_shallow_split_rejected():
    """Models too shallow for a valid tripartite split (p >= 1, q >= 1,
    o = 2 needs M >= 4) are rejected at construction instead of
    silently wrapping negative block indices (the train/eval-mismatch
    bug behind chance-level accuracy on 2-layer configs)."""
    with pytest.raises(ValueError, match="too shallow"):
        Federation(FedConfig(**dict(BERT_GATE, layers=3)))
