"""Theorem 1 sanity on a controlled testbed: O(1/sqrt(G)) decay of the
average gradient norm plus a non-vanishing non-IID floor (sigma_2^2)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import fedavg


def _make_clients(n_clients, d, hetero, seed=0):
    """Quadratic clients F_n(x) = ||x - c_n||^2 with spread ~ hetero,
    shared offset so x0=0 is far from every optimum."""
    rng = np.random.default_rng(seed)
    centers = 3.0 + hetero * rng.standard_normal((n_clients, d))
    return jnp.asarray(centers, jnp.float32)


def _run_fed(centers, g_rounds, local_steps=4, lr=None, sketch_noise=0.0,
             seed=0):
    n, d = centers.shape
    key = jax.random.PRNGKey(seed)
    x = jnp.zeros(d)
    grad_norms = []
    for g in range(g_rounds):
        step = lr if lr else 1.0 / np.sqrt(g_rounds)
        locals_ = []
        for c in range(n):
            xn = x
            for _ in range(local_steps):
                grad = 2 * (xn - centers[c])
                if sketch_noise:
                    key, k2 = jax.random.split(key)
                    grad = grad + sketch_noise * jax.random.normal(k2, (d,))
                xn = xn - step * grad
            locals_.append({"x": xn})
        x = fedavg(locals_, [1.0] * n)["x"]
        global_grad = 2 * (x - centers.mean(0))
        grad_norms.append(float(jnp.sum(global_grad ** 2)))
    return np.asarray(grad_norms)


def test_convergence_rate_scales_with_sqrt_g():
    """With eta = 1/sqrt(G) and persistent gradient noise, the residual
    noise ball scales like eta^2 ~ 1/G (Theorem 1's vanishing
    sigma_local/sqrt(G) term)."""
    centers = _make_clients(8, 16, hetero=0.0)
    short = _run_fed(centers, 16, sketch_noise=0.5)[-4:].mean()
    long = _run_fed(centers, 256, sketch_noise=0.5)[-4:].mean()
    assert long < short * 0.5


def test_noniid_floor_grows_with_heterogeneity():
    """sigma_2^2 term: more heterogeneity -> higher residual."""
    tails = []
    for hetero in (0.1, 2.0):
        centers = _make_clients(8, 16, hetero=hetero, seed=1)
        norms = _run_fed(centers, 128, lr=0.05)
        tails.append(norms[-16:].mean())
    assert tails[1] > tails[0]


def test_sketch_noise_vanishes_with_g():
    """sigma_local^2/sqrt(G): noisy-channel runs still converge, slower."""
    centers = _make_clients(6, 8, hetero=0.0, seed=2)
    clean = _run_fed(centers, 128)
    noisy = _run_fed(centers, 128, sketch_noise=0.5)
    assert noisy[-16:].mean() < noisy[:16].mean()   # still converging
    assert clean[-16:].mean() <= noisy[-16:].mean() + 1e-6
