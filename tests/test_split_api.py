"""SplitModel protocol + registry: BERT bit-parity with the pre-refactor
path, causal-LM end-to-end federation, cohort bucket padding, and the
no-architecture-imports invariant of the refactor.

The BERT parity tests pin the acceptance criterion that routing the
paper's model through the model-agnostic API changes *nothing*:

- op-level: the generic ``split_forward`` emits bit-identical values to
  the pre-refactor BERT-inlined implementation (replicated here);
- run-level: ``Federation(FedConfig(model="bert-base"))`` reproduces the
  history recorded from the pre-refactor code (``tests/golden/
  bert_parity.json``, same seed, plain f32) to float precision.
"""
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import golden_env
from repro.configs import get_config
from repro.core.sketch import make_plan
from repro.core.split_training import (Channel, IDENTITY_CHANNEL, Split,
                                       split_forward)
from repro.core.ssop import make_ssop
from repro.federation.simulation import FedConfig, Federation
from repro.models import bert as bert_mod
from repro.models.params import init_tree
from repro.models.split_api import (BertSplitModel, CausalLMSplitModel,
                                    as_split_model, available_split_models,
                                    get_split_model, split_model_for)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "bert_parity.json")
GOLDEN_PRODUCT = os.path.join(os.path.dirname(__file__), "golden",
                              "bert_parity_product.json")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_contents_and_resolution():
    names = available_split_models()
    assert "bert-base" in names and "llama3-8b" in names
    m = get_split_model("bert-base", num_layers=4)
    assert isinstance(m, BertSplitModel) and m.num_blocks == 4
    lm = get_split_model("llama3-8b", num_layers=4, dtype="float32")
    assert isinstance(lm, CausalLMSplitModel)
    assert lm.cfg.param_dtype == "float32" and lm.num_blocks == 4
    with pytest.raises(KeyError):
        get_split_model("not-a-model")
    # ArchConfig adaptation is cached per config
    cfg = get_config("bert-base").reduced()
    assert split_model_for(cfg) is split_model_for(cfg)
    assert as_split_model(split_model_for(cfg)) is split_model_for(cfg)
    # MoE / non-uniform decoders are rejected with a clear error
    with pytest.raises(NotImplementedError):
        split_model_for(get_config("grok-1-314b").reduced())


def test_fedconfig_bert_layers_shim_warns_and_maps_to_layers():
    import dataclasses

    with pytest.warns(DeprecationWarning):
        fc = FedConfig(n_clients=2, bert_layers=3)
    assert fc.layers == 3 and fc.bert_layers == 3
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # no warning on the new name
        fc2 = FedConfig(n_clients=2, layers=5)
        # reconstruction round-trips (bert_layers mirrors layers after
        # resolution) must stay warning-free too
        fc3 = dataclasses.replace(fc, lr=1e-3)
        FedConfig(**dataclasses.asdict(fc))
    assert fc2.layers == 5 and fc3.layers == 3


def test_protocol_cost_facts():
    m = get_split_model("bert-base", num_layers=4)
    assert m.activation_shape(2, 16) == (2, 16, m.cfg.d_model)
    blk, head = m.block_param_count(4), m.head_param_count(4)
    assert blk > 0 and head > 0
    assert m.flops_per_token(num_classes=4) == pytest.approx(
        6.0 * (4 * blk + head))
    # a split bills only the client-side parts
    s = Split(1, 2, 1)
    assert m.flops_per_token(s, num_classes=4) == pytest.approx(
        6.0 * (2 * blk + head))
    lm = get_split_model("llama3-8b", num_layers=4)
    assert lm.task == "causal-lm" and lm.head_param_count() > 0


def test_no_arch_imports_in_core_federation_runtime():
    """Acceptance: core/, federation/, runtime/ never name BERT."""
    import repro
    root = list(repro.__path__)[0]
    for pkg in ("core", "federation", "runtime"):
        for fn in os.listdir(os.path.join(root, pkg)):
            if not fn.endswith(".py"):
                continue
            src = open(os.path.join(root, pkg, fn)).read()
            assert "models.bert" not in src and "import bert" not in src, \
                f"{pkg}/{fn} still imports repro.models.bert"


# ---------------------------------------------------------------------------
# BERT bit-parity with the pre-refactor path
# ---------------------------------------------------------------------------

def _legacy_bert_split_forward(cfg, frozen, lora, tokens, split, channel,
                               mask_valid=None):
    """The pre-refactor BERT-inlined split forward, verbatim."""
    x = bert_mod.embed(cfg, frozen, tokens)
    h_up = bert_mod.run_blocks(cfg, frozen, lora, x, 0, split.p, mask_valid)
    h_up_t = channel(h_up)
    h_down = bert_mod.run_blocks(cfg, frozen, lora, h_up_t,
                                 split.p, split.p + split.q, mask_valid)
    h_down_t = channel(h_down)
    x = bert_mod.run_blocks(cfg, frozen, lora, h_down_t,
                            split.p + split.q, cfg.num_layers, mask_valid)
    cls = x[:, 0, :]
    pooled = jnp.tanh(cls @ lora["pooler"]["w"].astype(cls.dtype)
                      + lora["pooler"]["b"].astype(cls.dtype))
    logits = pooled @ lora["head"]["w"].astype(cls.dtype) \
        + lora["head"]["b"].astype(cls.dtype)
    return cls, logits


def test_bert_split_forward_bitwise_matches_legacy_ops():
    cfg = get_config("bert-base").reduced().with_(num_layers=4)
    model = split_model_for(cfg)
    tree = init_tree(bert_mod.bert_specs(cfg, 4), jax.random.PRNGKey(0),
                     jnp.float32)
    frozen, lora = tree["frozen"], tree["lora"]
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                              cfg.vocab_size)
    emb = jax.random.normal(jax.random.PRNGKey(3), (16, cfg.d_model))
    plan = make_plan(cfg.d_model, 3, cfg.d_model // 2, seed=2)
    for channel in (IDENTITY_CHANNEL,
                    Channel(make_ssop(emb, 4, "salt", 0), plan)):
        for split in (Split(1, 1, 2), Split(2, 1, 1)):
            cls_l, log_l = _legacy_bert_split_forward(
                cfg, frozen, lora, toks, split, channel)
            cls_n, log_n, _, _ = split_forward(model, frozen, lora, toks,
                                               split, channel)
            np.testing.assert_array_equal(np.asarray(cls_l),
                                          np.asarray(cls_n))
            np.testing.assert_array_equal(np.asarray(log_l),
                                          np.asarray(log_n))


def _assert_matches_golden(path):
    gold = json.load(open(path))
    kw = dict(gold["config"])
    if "bert_layers" in kw:
        kw["layers"] = kw.pop("bert_layers")    # golden predates the rename
    kw["poisoned"] = tuple(kw.get("poisoned", ()))
    fed = Federation(FedConfig(**kw), backend="batched")
    h = fed.run(gold["run"]["method"],
                global_rounds=gold["run"]["global_rounds"],
                steps_per_round=gold["run"]["steps_per_round"])
    # in the golden's recording environment (tests/golden_env.py) the
    # history must match at float precision; in a drifted container XLA
    # codegen changes shift f32 bits and the chaotic gradient map
    # amplifies them to ~1e-3 over this horizon, so fall back to a band
    # that still catches wiring bugs (re-pin: tests/golden/
    # regen_bert_parity.py)
    strict = golden_env.matches(gold.get("env"))
    rtol, atol = (0, 1e-9) if strict else (0.05, 0.1)
    np.testing.assert_allclose(h["loss"], gold["loss"], rtol=rtol,
                               atol=atol)
    np.testing.assert_allclose(h["accuracy"], gold["accuracy"], rtol=rtol,
                               atol=atol)
    np.testing.assert_allclose(h["delta"], gold["delta"], rtol=rtol,
                               atol=atol)
    assert h["round"] == gold["round"]
    for n, ref in gold["client_losses"].items():
        np.testing.assert_allclose(h["client_losses"][int(n)], ref,
                                   rtol=rtol, atol=atol)
    sums = [float(np.asarray(l, np.float64).sum())
            for l in jax.tree_util.tree_leaves(fed.last_theta)]
    np.testing.assert_allclose(sums, gold["theta_leaf_sums"], rtol=rtol,
                               atol=1e-7 if strict else atol)


def test_bert_federation_matches_prerefactor_golden():
    """Run-level parity: same seed + f32 + the legacy factor-averaging
    flag reproduces the history recorded from the pre-refactor code
    (atol 1e-9 ≈ bit-identical for f32).  The golden's config carries
    ``aggregate: "factor"`` — it was recorded under factor averaging,
    and that path must stay bit-frozen under the product-space default."""
    _assert_matches_golden(GOLDEN)


def test_bert_federation_matches_product_golden():
    """The product-space aggregation path is pinned by its own golden
    (same config/seed as the legacy one, ``aggregate: "product"``), so
    future refactors of the delta-tree fusion are bit-anchored too."""
    _assert_matches_golden(GOLDEN_PRODUCT)


# ---------------------------------------------------------------------------
# causal LM end to end
# ---------------------------------------------------------------------------

def test_causal_lm_split_equals_full_forward_without_channel():
    model = get_split_model("llama3-8b", num_layers=4)
    tree = init_tree(model.specs(), jax.random.PRNGKey(0), jnp.float32)
    frozen, lora = tree["frozen"], tree["lora"]
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              model.cfg.vocab_size)
    _, full_logits = model.forward(frozen, lora, toks)
    for split in (Split(1, 1, 2), Split(1, 2, 1), Split(2, 1, 1)):
        _, logits, h_up, h_down = split_forward(model, frozen, lora, toks,
                                                split, IDENTITY_CHANNEL)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits), atol=1e-5)
        assert h_up.shape == model.activation_shape(2, 12)
        assert h_down.shape == model.activation_shape(2, 12)
    # the LM loss is a finite per-example next-token CE
    batch = {"tokens": toks}
    per = model.per_example_loss(full_logits, batch)
    assert per.shape == (2,) and bool(np.isfinite(per).all())


CAUSAL_KW = dict(n_clients=4, n_edges=2, alpha=0.2, poisoned=(1,),
                 total_examples=400, probe_q=8, local_warmup_steps=2,
                 lr=5e-3, layers=4, t_rounds=1, batch_size=8, seed=0,
                 model="llama3-8b")


@pytest.mark.parametrize("backend", ["batched", "reference"])
def test_causal_lm_fed_round_smoke(backend):
    """Acceptance: a causal LM completes a full Federation.run —
    clustering, dynamic splits, SS-OP∘sketch channel, edge/cloud
    aggregation — on both backends."""
    fed = Federation(FedConfig(**CAUSAL_KW), backend=backend)
    h = fed.run("elsa", global_rounds=1, steps_per_round=2)
    assert np.isfinite(h["loss"]).all()
    assert 0.0 <= h["final_accuracy"] <= 1.0
    assert any(len(v) for v in h["client_losses"].values())
    # poisoned client 1 carries scrambled *tokens* under the LM task
    assert fed.data[1].poisoned


# ---------------------------------------------------------------------------
# cohort bucket padding (deadline recompile-churn fix)
# ---------------------------------------------------------------------------

def test_engine_bucket_ladder():
    from repro.federation.engine import BUCKET_LADDER, bucket_size
    assert all(bucket_size(n) == n for n in range(1, 9))   # small = exact
    for n in (9, 11, 13, 17, 33):
        s = bucket_size(n)
        assert s >= n and s in BUCKET_LADDER
        assert (s - n) / n <= 0.25 + 1e-9                  # bounded waste
    # beyond the ladder's top entry: the next shard-multiple of n itself
    # (the old lcm(16, multiple) stepping over-padded, e.g. 65 -> 80)
    assert bucket_size(65) == 65 and bucket_size(100) == 100
    assert bucket_size(65, 3) == 66 and bucket_size(100, 8) == 104
    assert bucket_size(9, 3) == 12                         # ladder + multiple


def test_engine_padded_cohorts_share_one_compile_and_stay_exact():
    """Cohorts of 9 and 10 clients pad to the same bucket (10): one
    compiled executable serves both, and phantom rows change nothing for
    the real clients (bitwise)."""
    from repro.data.pipeline import infinite_batches

    kw = dict(n_clients=10, n_edges=2, alpha=0.5, poisoned=(),
              total_examples=800, probe_q=8, local_warmup_steps=2,
              lr=5e-3, layers=4, t_rounds=1, batch_size=8, seed=0)

    def run(pad, clients):
        fed = Federation(FedConfig(**kw))
        fed.engine.pad_cohorts = pad
        iters = {n: infinite_batches(fed.data[n].tokens,
                                     fed.data[n].labels, 8, seed=100 + n)
                 for n in range(10)}
        res = fed.group_steps(clients, fed.lora0, 2, iters,
                              use_split=False)
        return fed, res

    fed_p, res_p = run(True, list(range(9)))       # 9 -> padded to 10
    _, res_u = run(False, list(range(9)))          # 9 exact (no padding)
    for n in range(9):
        (lp, l1), (lu, l2) = res_p[n], res_u[n]
        assert l1 == l2
        for a, b in zip(jax.tree_util.tree_leaves(lp),
                        jax.tree_util.tree_leaves(lu)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # a 10-cohort on the padded engine reuses the 9-cohort's executable
    iters = {n: infinite_batches(fed_p.data[n].tokens,
                                 fed_p.data[n].labels, 8, seed=200 + n)
             for n in range(10)}
    fed_p.group_steps(list(range(10)), fed_p.lora0, 2, iters,
                      use_split=False)
    sizes = fed_p.engine.compile_cache_sizes()
    assert all(v == 1 for v in sizes.values()), sizes
