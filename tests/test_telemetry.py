"""Federation telemetry: registry semantics, the zero-overhead-disabled
contract, trace<->metrics consistency, and the compile-churn gate.

The two load-bearing guarantees (docs/observability.md):

- **bit-inertness** — a telemetry-enabled run produces identical
  histories and event traces to a disabled one (telemetry is host-side
  bookkeeping only, it never touches device arrays or RNG);
- **trace<->metrics agreement** — ``runtime.events{kind=...}`` counters
  are bridged from :meth:`EventTrace.log` itself, so they must equal
  ``trace.summary()`` exactly, faults and churn included.
"""
import json
import os

import numpy as np
import pytest

from repro import telemetry as tm
from repro.federation.simulation import FedConfig, Federation
from repro.federation.topology import make_churn_trace, make_fault_trace
from repro.runtime import RuntimeConfig
from repro.runtime.trace import EventTrace

SMALL_KW = dict(n_clients=6, n_edges=2, alpha=0.2, poisoned=(4,),
                total_examples=600, probe_q=8, local_warmup_steps=2,
                lr=2e-2, layers=4, t_rounds=1, batch_size=16, seed=0)

DATA = os.path.join(os.path.dirname(__file__), "data")


@pytest.fixture(autouse=True)
def _telemetry_off():
    """Tests must not leak an enabled collector into each other (or
    into the rest of the suite)."""
    tm.disable()
    yield
    tm.disable()


# ---------------------------------------------------------------------------
# registry semantics (no model, fast)
# ---------------------------------------------------------------------------

def test_flat_key_sorts_labels():
    assert tm.flat_key("a", {}) == "a"
    assert tm.flat_key("a", {"b": 1, "a": 2}) == "a{a=2,b=1}"


def test_counters_gauges_histograms():
    tel = tm.Telemetry()
    tel.inc("c", 2, kind="x")
    tel.inc("c", 3, kind="x")
    tel.inc("c", 1, kind="y")
    assert tel.counter("c", kind="x") == 5
    assert tel.counters_by_name("c") == {"c{kind=x}": 5.0, "c{kind=y}": 1.0}
    tel.set_gauge("g", 1.0)
    tel.set_gauge("g", 7.0)
    assert tel.gauge("g") == 7.0
    tel.observe("h", 0.002)
    tel.observe("h", 50.0)          # beyond the last bound -> overflow
    h = tel.histograms["h"]
    assert h.count == 2 and h.max == 50.0 and h.counts[-1] == 1


def test_histogram_rejects_unsorted_buckets():
    with pytest.raises(ValueError):
        tm.Histogram((1.0, 0.5))


def test_round_records_hold_counter_deltas():
    tel = tm.Telemetry()
    tel.inc("c", 5)
    tel.end_round(0)
    tel.inc("c", 2)
    with tel.span("uplink", edge=1) as sp:
        sp.set(sim_s=3.0)
    tel.end_round(1, sim_time_s=10.0)
    r0, r1 = tel.rounds
    assert r0["counters"] == {"c": 5.0} and r1["counters"] == {"c": 2.0}
    assert r1["sim_time_s"] == 10.0
    assert r1["spans"][0]["name"] == "uplink"
    assert r1["spans"][0]["attrs"]["sim_s"] == 3.0
    assert tel.counter("c") == 7                    # cumulative unharmed


def test_disabled_module_helpers_are_noops():
    assert not tm.enabled() and tm.get() is None
    tm.inc("c")
    tm.set_gauge("g", 1.0)
    tm.observe("h", 1.0)
    tm.end_round(0)
    assert tm.export("/nonexistent/should-not-write") is None
    assert tm.summary() is None
    sp = tm.span("x")
    assert isinstance(sp, tm.NullSpan)
    with sp as s:
        s.set(anything=1)           # still a no-op


def test_session_nests_and_restores():
    outer = tm.enable({"level": "outer"})
    with tm.session({"level": "inner"}) as inner:
        assert tm.get() is inner
        tm.inc("c")
    assert tm.get() is outer
    assert inner.counter("c") == 1 and outer.counter("c") == 0


def test_export_read_roundtrip(tmp_path):
    with tm.session({"m": 1}) as tel:
        tel.inc("c", 4)
        tel.record_span("uplink", dur_s=0.5, sim_s=2.0)
        tel.end_round(0)
        path = tm.export_jsonl(tel, str(tmp_path / "t.jsonl"))
    d = tm.read_jsonl(path)
    assert d["meta"]["meta"] == {"m": 1}
    assert d["summary"]["counters"] == {"c": 4.0}
    assert d["summary"]["spans"]["uplink"] == {"count": 1, "wall_s": 0.5,
                                               "sim_s": 2.0}
    # killed run: strip the summary line, read_jsonl rebuilds it from
    # the per-round deltas
    lines = open(path).read().strip().split("\n")
    (tmp_path / "cut.jsonl").write_text("\n".join(lines[:-1]) + "\n")
    d2 = tm.read_jsonl(str(tmp_path / "cut.jsonl"))
    assert d2["summary"]["counters"] == {"c": 4.0}
    assert d2["summary"]["spans"]["uplink"]["sim_s"] == 2.0


def test_flush_pending_folds_leftovers(tmp_path):
    with tm.session() as tel:
        tel.inc("c", 1)             # never end_round-ed
        path = tm.export_jsonl(tel, str(tmp_path / "t.jsonl"))
    d = tm.read_jsonl(path)
    assert len(d["rounds"]) == 1 and d["rounds"][0]["round"] is None
    assert d["summary"]["counters"] == {"c": 1.0}


# ---------------------------------------------------------------------------
# EventTrace per-kind index (satellite: O(1) of_kind/count)
# ---------------------------------------------------------------------------

def test_trace_index_matches_linear_scan():
    tr = EventTrace()
    for i in range(20):
        tr.log(float(i), "a" if i % 3 else "b", client=i, round=i)
    assert tr.count("a") == sum(1 for r in tr.records if r[1] == "a")
    assert tr.of_kind("b") == [r for r in tr.records if r[1] == "b"]
    assert tr.of_kind("missing") == [] and tr.count("missing") == 0
    assert tr.summary() == {"b": 7, "a": 13}
    # index rows are the same tuples as the flat log, not copies
    assert tr.of_kind("a")[0] is tr.records[1]


def test_trace_records_setter_rebuilds_index():
    tr = EventTrace()
    tr.log(0.0, "a")
    src = EventTrace()
    src.log(1.0, "b")
    src.log(2.0, "b")
    tr.records = list(src.records)          # checkpoint-resume shape
    assert tr.count("a") == 0 and tr.count("b") == 2
    assert tr == src
    tr.log(3.0, "b")
    assert tr.count("b") == 3 and len(tr) == 3


# ---------------------------------------------------------------------------
# end-to-end: bit-inertness + trace<->metrics agreement
# ---------------------------------------------------------------------------

def _sync_run(enabled: bool):
    tel = tm.enable() if enabled else None
    try:
        fed = Federation(FedConfig(**SMALL_KW, screen=True))
        faults = make_fault_trace(SMALL_KW["n_clients"], faulty_frac=0.5,
                                  crash_rate=0.2, corrupt_rate=0.7,
                                  corrupt_modes=("nan",), seed=3)
        churn = make_churn_trace(SMALL_KW["n_clients"], 1e6,
                                 churn_frac=0.5, seed=7)
        h = fed.run("elsa-nocluster", global_rounds=2, steps_per_round=2,
                    runtime=RuntimeConfig(policy="sync", faults=faults,
                                          churn=churn))
    finally:
        tm.disable()
    return h, tel


@pytest.fixture(scope="module")
def sync_runs():
    """One telemetry-off + one telemetry-on seeded sync run with faults
    and churn, shared by the parity/counter/span/verdict tests."""
    h_off, _ = _sync_run(enabled=False)
    h_on, tel = _sync_run(enabled=True)
    return h_off, h_on, tel


def test_enabled_run_is_bit_inert_and_counts_match_trace(sync_runs):
    h_off, h_on, tel = sync_runs
    # acceptance: identical histories and traces either way
    assert h_on["accuracy"] == h_off["accuracy"]
    assert h_on["loss"] == h_off["loss"]
    assert h_on["time"] == h_off["time"]
    assert h_on["trace"] == h_off["trace"]
    # acceptance: every event kind's counter equals the trace exactly
    summary = h_on["trace"].summary()
    assert summary  # the run must actually have produced events
    for kind, n in summary.items():
        assert tel.counter("runtime.events", kind=kind) == n, kind
    # and no counter series invents event kinds the trace lacks
    bridged = tel.counters_by_name("runtime.events")
    assert len(bridged) == len(summary)
    # per-phase simulated seconds and wire bytes accumulated
    assert tel.counter("runtime.sim.compute_s") > 0
    assert tel.counter("runtime.uplink_bytes") > 0
    # one round record per global round, stamped with the simulated clock
    assert [r["round"] for r in tel.rounds] == [0, 1]
    assert tel.rounds[-1]["sim_time_s"] == pytest.approx(h_on["time"][-1])


def test_round_lifecycle_spans_recorded(sync_runs):
    _, _, tel = sync_runs
    names = {s["name"] for rec in tel.rounds for s in rec["spans"]}
    assert {"dispatch", "local_steps", "uplink", "edge_agg", "cloud_agg",
            "eval"} <= names
    uplinks = [s for rec in tel.rounds for s in rec["spans"]
               if s["name"] == "uplink"]
    # uplink spans carry the edge-round's simulated barrier wait
    assert all("sim_s" in s["attrs"] for s in uplinks)
    assert any(s["attrs"]["sim_s"] > 0 for s in uplinks)


def test_screening_metrics_follow_verdicts(sync_runs):
    _, _, tel = sync_runs
    verdicts = tel.counters_by_name("screening.verdicts")
    assert verdicts, "screened run must record verdict counters"
    assert tel.counter("screening.verdicts", verdict="nonfinite") > 0
    assert 0.0 < tel.gauge("screening.trust_mean") <= 1.0


# ---------------------------------------------------------------------------
# engine compile accounting (satellite: recompile-churn regression gate)
# ---------------------------------------------------------------------------

def test_deadline_scheduler_compiles_once_per_split_bucket():
    """Varying deadline-window cohorts must reuse compiled executables:
    exactly one jit compile per (split, ladder-bucket) — recompile
    churn would show as a counter exceeding its cache entry."""
    tel = tm.enable()
    try:
        fed = Federation(FedConfig(**SMALL_KW))
        churn = make_churn_trace(SMALL_KW["n_clients"], 1e6,
                                 churn_frac=0.5, seed=7)
        fed.run("elsa-nocluster", global_rounds=3, steps_per_round=2,
                runtime=RuntimeConfig(policy="deadline", churn=churn,
                                      deadline_quantile=0.5))
        compiles = tel.counters_by_name("engine.jit_compiles")
        assert compiles, "run must have compiled at least one round fn"
        # one compile per (split, bucket) series, never a recompile
        assert all(v == 1 for v in compiles.values()), compiles
        # counters agree with the engine's own jit cache sizes: total
        # compiles == total specialized executables
        cache = fed.engine.compile_cache_sizes()
        assert sum(compiles.values()) == sum(cache.values())
        assert tel.counter("engine.clients") > 0
        disp = tel.histograms.get("engine.dispatch_s{compiled=True}")
        assert disp is not None and disp.count == sum(cache.values())
    finally:
        tm.disable()


# ---------------------------------------------------------------------------
# checkpoint + report surfaces
# ---------------------------------------------------------------------------

def test_checkpoint_metrics(tmp_path):
    from repro.checkpoint import CheckpointConfig
    from repro.checkpoint.federation import latest_checkpoint, load_state
    tel = tm.enable()
    try:
        fed = Federation(FedConfig(**SMALL_KW))
        fed.run("elsa-nocluster", global_rounds=1, steps_per_round=2,
                runtime=RuntimeConfig(policy="sync"),
                checkpoint=CheckpointConfig(dir=str(tmp_path), every=1))
        load_state(latest_checkpoint(str(tmp_path)))
    finally:
        tm.disable()
    assert tel.counter("checkpoint.saves") == 1
    assert tel.counter("checkpoint.restores") == 1
    assert tel.counter("checkpoint.bytes_written") > 0
    assert tel.counter("checkpoint.bytes_read") \
        == tel.counter("checkpoint.bytes_written")
    assert tel.histograms["checkpoint.save_s"].count == 1


def test_serving_metrics_and_adapter_swap():
    from repro.configs import get_config
    from repro.serving import ServingEngine
    tel = tm.enable()
    try:
        eng = ServingEngine(get_config("qwen2.5-3b").reduced(),
                            batch_size=1, max_len=48, seed=0)
        eng.submit([1, 2, 3], max_new_tokens=3)
        eng.run_until_drained()
        eng.swap_adapter(eng.lora)
    finally:
        tm.disable()
    assert tel.counter("serving.requests") == 1
    assert tel.counter("serving.tokens") == 3
    assert tel.counter("serving.adapter_swaps") == 1
    assert tel.histograms["serving.request_s"].count == 1


def test_report_renders_committed_example():
    """Acceptance: the report CLI renders a per-phase breakdown from
    the committed example JSONL (a real screened sync run with
    corruption faults on the reduced federation)."""
    from repro.analysis.telemetry_report import render
    path = os.path.join(DATA, "telemetry_example.jsonl")
    d = tm.read_jsonl(path)
    out = render(d, show_rounds=True)
    # the per-phase table, in lifecycle order
    assert out.index("local_steps") < out.index("uplink") \
        < out.index("edge_agg") < out.index("cloud_agg")
    # simulated-cost and bytes breakdown
    assert "simulated cost" in out and "wire: uplink" in out
    # events, compile accounting, screening, histograms all surface
    assert "runtime events" in out and "jit compiles" in out
    assert "screening verdicts" in out and "histograms" in out
    # per-round table present with both closed rounds
    assert "round     sim_time" in out
    # counters in the committed file agree with its own trace bridge
    ev = {k: v for k, v in d["summary"]["counters"].items()
          if k.startswith("runtime.events")}
    assert sum(ev.values()) == sum(
        sum(r["counters"].get(k, 0) for k in ev) for r in d["rounds"])


def test_report_main_prints(capsys):
    import sys
    from repro.analysis import telemetry_report
    argv = sys.argv
    sys.argv = ["telemetry_report",
                os.path.join(DATA, "telemetry_example.jsonl")]
    try:
        telemetry_report.main()
    finally:
        sys.argv = argv
    out = capsys.readouterr().out
    assert "telemetry summary" in out and "phase" in out


# ---------------------------------------------------------------------------
# streaming sinks (docs/observability.md)
# ---------------------------------------------------------------------------

def _fill(tel, rounds, spans_per_round=2):
    for g in range(rounds):
        tel.inc("x.events", 3)
        for s in range(spans_per_round):
            tel.record_span("phase", dur_s=0.01, idx=s)
        tel.end_round(g)


def test_jsonl_sink_streams_rounds_live(tmp_path):
    """Every completed round is on disk the moment it closes (a killed
    run loses at most the open round), and close() appends the
    summary so the file parses like an exported JSONL."""
    p = str(tmp_path / "t.jsonl")
    sink = tm.JsonlSink(p)
    tel = tm.Telemetry({"bench": "sink"}, sink=sink)
    _fill(tel, 3)
    lines = [json.loads(l) for l in open(p)]
    assert lines[0]["type"] == "meta" and lines[0]["meta"] == {
        "bench": "sink"}
    assert [l["round"] for l in lines[1:]] == [0, 1, 2]
    tm.finalize_sink(tel)
    d = tm.read_jsonl(p)
    assert len(d["rounds"]) == 3
    assert d["summary"]["counters"]["x.events"] == 9
    sink.close()                                   # idempotent


def test_jsonl_sink_rotation_parts_parse_standalone(tmp_path):
    p = str(tmp_path / "t.jsonl")
    sink = tm.JsonlSink(p, rotate_bytes=600)
    tel = tm.Telemetry({"bench": "rot"}, sink=sink)
    _fill(tel, 12)
    tm.finalize_sink(tel)
    assert sink.parts >= 1
    rounds_seen = []
    for part in sink.rotated_paths() + [p]:
        d = tm.read_jsonl(part)                    # meta line re-stamped
        assert d["meta"]["meta"] == {"bench": "rot"}
        rounds_seen += [r["round"] for r in d["rounds"]]
    assert rounds_seen == list(range(12))          # nothing lost/reordered


def test_retain_rounds_bounds_memory_not_disk(tmp_path):
    p = str(tmp_path / "t.jsonl")
    tel = tm.Telemetry(sink=tm.JsonlSink(p), retain_rounds=2)
    _fill(tel, 8)
    assert [r["round"] for r in tel.rounds] == [6, 7]   # window trimmed
    tm.finalize_sink(tel)
    assert len(tm.read_jsonl(p)["rounds"]) == 8          # disk complete
    with pytest.raises(ValueError):
        tm.Telemetry(retain_rounds=-1)
    with pytest.raises(ValueError):
        tm.JsonlSink(str(tmp_path / "x.jsonl"), rotate_bytes=-1)


def test_session_with_sink_finalizes_on_exit(tmp_path):
    p = str(tmp_path / "s.jsonl")
    with tm.session(meta={"m": 1}, sink=tm.JsonlSink(p)) as tel:
        tel.inc("a")
        tel.end_round(0)
        tel.inc("b")                               # trailing partial round
    d = tm.read_jsonl(p)
    assert len(d["rounds"]) == 2 and d["rounds"][1]["round"] is None
    assert d["summary"]["counters"] == {"a": 1.0, "b": 1.0}
    assert tm.get() is None                        # previous state restored


def test_no_sink_path_is_unchanged():
    """The default in-memory collector never references a sink: runs
    without one keep the historical behavior bit-for-bit."""
    tel = tm.Telemetry()
    _fill(tel, 2)
    assert tel.sink is None and len(tel.rounds) == 2
    tm.finalize_sink(tel)                          # no-op without a sink
    assert len(tel.rounds) == 2                    # no flush side-effect
