"""Event-driven edge runtime: sync parity, determinism, policy behavior.

Parity needs no x64 tricks here (unlike the batched-vs-reference engine
tests): the sync scheduler issues the *exact same* sequence of compiled
training and aggregation calls as ``Federation.run`` on the same
backend, so histories must match bit-for-bit in plain float32.
"""
import numpy as np
import pytest

from repro.core.split_training import Split
from repro.federation.simulation import FedConfig, Federation
from repro.federation.topology import (ChurnTrace, always_on,
                                       make_churn_trace, make_topology)
from repro.runtime import EdgeRuntime, RuntimeConfig
from repro.runtime.events import Event, EventQueue

SMALL_KW = dict(n_clients=6, n_edges=2, alpha=0.2, poisoned=(4,),
                total_examples=600, probe_q=8, local_warmup_steps=2,
                lr=2e-2, layers=4, t_rounds=1, batch_size=16, seed=0)


# ---------------------------------------------------------------------------
# pure-core pieces (no model, fast)
# ---------------------------------------------------------------------------

def test_event_queue_deterministic_fifo_ties():
    q = EventQueue()
    q.push(Event(2.0, "b", client=1))
    q.push(Event(1.0, "a", client=2))
    q.push(Event(1.0, "a", client=3))     # same time: FIFO, not client order
    assert [e.client for e in q.drain_until(1.0)] == [2, 3]
    assert q.pop().client == 1
    assert not q


def test_churn_trace_pause_resume():
    tr = ChurnTrace([np.array([[5.0, 8.0], [20.0, 25.0]])], 100.0)
    assert tr.is_online(0, 4.9) and not tr.is_online(0, 5.0)
    assert tr.next_online(0, 6.0) == 8.0
    # 4s of work from t=3: 2s before the outage, pause 5..8, 2s after
    assert tr.finish_time(0, 3.0, 4.0) == pytest.approx(10.0)
    # work started inside an outage begins at rejoin
    assert tr.finish_time(0, 6.0, 1.0) == pytest.approx(9.0)
    # work spanning two outages pauses across both
    assert tr.finish_time(0, 3.0, 20.0) == pytest.approx(31.0)


def test_make_churn_trace_deterministic_and_bounded():
    a = make_churn_trace(8, 500.0, churn_frac=0.5, seed=3)
    b = make_churn_trace(8, 500.0, churn_frac=0.5, seed=3)
    for ia, ib in zip(a.offline, b.offline):
        np.testing.assert_array_equal(ia, ib)
    churny = sum(len(iv) > 0 for iv in a.offline)
    assert churny <= 4                     # only churn_frac of clients cycle
    assert all(tr.shape[1] == 2 for tr in a.offline if tr.size)
    on = always_on(8)
    assert on.is_online(3, 1e9) and on.finish_time(3, 2.0, 5.0) == 7.0


def test_cost_model_monotone_in_capacity_and_split():
    from repro.core.comm_model import comm_config_from
    from repro.runtime.cost import ClientCostModel
    from repro.configs import get_config

    cfg = get_config("bert-base").reduced().with_(
        num_layers=8, param_dtype="float32", activation_dtype="float32")
    topo = make_topology(4, 2, seed=0)
    topo.capacity[:] = [1e9, 2e9, 4e9, 8e9]
    topo.bandwidth[:] = 1e7
    fed = FedConfig(n_clients=4, n_edges=2)
    comm = comm_config_from(cfg, fed)
    cm = ClientCostModel(cfg, topo, comm, batch_size=16, num_classes=4)
    ts = [cm.round_cost(n, Split(2, 4, 2), 4).total_s for n in range(4)]
    assert ts == sorted(ts, reverse=True)  # faster device -> less time
    # deeper client-side split -> more client FLOPs -> more time
    shallow = cm.round_cost(0, Split(1, 5, 2), 4).total_s
    deep = cm.round_cost(0, Split(3, 3, 2), 4).total_s
    assert deep > shallow
    assert cm.round_cost(0, Split(2, 4, 2), 4).comm_s > 0


def test_cost_model_prices_downlink_broadcast():
    """The cloud->client model broadcast is priced alongside uplink and
    is monotone in lora size / downlink bandwidth."""
    import dataclasses

    from repro.core.comm_model import comm_config_from
    from repro.runtime.cost import ClientCostModel
    from repro.configs import get_config

    cfg = get_config("bert-base").reduced().with_(
        num_layers=8, param_dtype="float32", activation_dtype="float32")
    topo = make_topology(4, 2, seed=0)
    topo.bandwidth[:] = 1e7
    fed = FedConfig(n_clients=4, n_edges=2)
    comm = comm_config_from(cfg, fed)
    cm = ClientCostModel(cfg, topo, comm, batch_size=16, num_classes=4)
    rc = cm.round_cost(0, Split(2, 4, 2), 4)
    assert rc.downlink_s > 0
    assert rc.total_s == pytest.approx(rc.compute_s + rc.comm_s
                                       + rc.latency_s + rc.downlink_s)
    # broadcast bytes: doubling the model doubles the downlink time
    comm2 = dataclasses.replace(comm, lora_bytes=2 * comm.lora_bytes)
    cm2 = ClientCostModel(cfg, topo, comm2, batch_size=16, num_classes=4)
    assert cm2.round_cost(0, Split(2, 4, 2), 4).downlink_s \
        == pytest.approx(2 * rc.downlink_s)
    # faster downlink (higher asymmetry ratio) -> strictly less time
    prev = None
    for ratio in (1.0, 2.0, 4.0, 8.0):
        cmr = ClientCostModel(cfg, topo, comm, batch_size=16,
                              num_classes=4, downlink_ratio=ratio)
        t = cmr.round_cost(0, Split(2, 4, 2), 4)
        if prev is not None:
            assert t.downlink_s < prev.downlink_s
            assert t.total_s < prev.total_s
        prev = t
    # symmetric link: downlink == LoRA upload share of the uplink
    sym = ClientCostModel(cfg, topo, comm, batch_size=16, num_classes=4,
                          downlink_ratio=1.0)
    assert sym.round_cost(0, Split(2, 4, 2), 4).downlink_s \
        == pytest.approx(comm.lora_bytes / 1e7)


def test_constrained_frac_reaches_topology_through_fedconfig():
    base = Federation(FedConfig(**SMALL_KW))
    slow = Federation(FedConfig(**dict(SMALL_KW, constrained_frac=0.5)))
    assert slow.topo.capacity.min() < base.topo.capacity.min()
    assert (slow.topo.bandwidth <= base.topo.bandwidth + 1e-9).all()
    assert (slow.topo.capacity <= base.topo.capacity + 1e-9).all()


# ---------------------------------------------------------------------------
# full-runtime behavior (reduced BERT; module-scoped federations)
# ---------------------------------------------------------------------------

def test_sync_policy_reproduces_run_history():
    """Acceptance: runtime policy='sync' == Federation.run bit-for-bit."""
    h_ref = Federation(FedConfig(**SMALL_KW)).run(
        "elsa", global_rounds=2, steps_per_round=2)
    h_sync = Federation(FedConfig(**SMALL_KW)).run(
        "elsa", global_rounds=2, steps_per_round=2,
        runtime=RuntimeConfig(policy="sync"))
    assert h_sync["accuracy"] == h_ref["accuracy"]
    assert h_sync["loss"] == h_ref["loss"]
    assert h_sync["delta"] == h_ref["delta"]
    assert h_sync["round"] == h_ref["round"]
    for n in range(SMALL_KW["n_clients"]):
        assert h_sync["client_losses"][n] == h_ref["client_losses"][n]
    # and it gained a strictly increasing wall-clock axis
    t = h_sync["time"]
    assert len(t) == len(h_sync["round"]) and all(
        b > a for a, b in zip(t, t[1:]))


def _churny_config():
    kw = dict(SMALL_KW, constrained_frac=0.34, seed=1)
    churn = make_churn_trace(kw["n_clients"], 10_000.0, mean_on_s=40.0,
                             mean_off_s=15.0, churn_frac=0.5, seed=2)
    return kw, churn


@pytest.mark.parametrize("policy", ["deadline", "async"])
def test_runtime_deterministic_same_seed(policy):
    """Acceptance: same seed + config => identical event trace and
    final accuracy."""
    kw, churn = _churny_config()
    hs = []
    for _ in range(2):
        fed = Federation(FedConfig(**kw))
        hs.append(fed.run("fedavg", global_rounds=2, steps_per_round=2,
                          runtime=RuntimeConfig(policy=policy,
                                                churn=churn)))
    a, b = hs
    assert a["trace"] == b["trace"] and len(a["trace"]) > 0
    assert a["final_accuracy"] == b["final_accuracy"]
    assert a["time"] == b["time"]
    assert a["loss"] == b["loss"]


def test_deadline_and_async_structure_under_churn():
    kw, churn = _churny_config()
    fed = Federation(FedConfig(**kw))
    h_d = fed.run("elsa-nocluster", global_rounds=2, steps_per_round=2,
                  runtime=RuntimeConfig(policy="deadline", churn=churn))
    tr = h_d["trace"]
    assert tr.count("edge_agg") >= 2          # every edge round aggregated
    assert all(np.isfinite(h_d["accuracy"]))
    assert h_d["time"] == sorted(h_d["time"])
    # every aggregation folded at least one update
    for rec in tr.of_kind("edge_agg"):
        info = dict(rec[4])
        assert info["n_updates"] >= 1

    fed2 = Federation(FedConfig(**kw))
    h_a = fed2.run("elsa-nocluster", global_rounds=2, steps_per_round=2,
                   runtime=RuntimeConfig(policy="async", churn=churn))
    tra = h_a["trace"]
    assert tra.count("cloud_agg") == 2
    for rec in tra.of_kind("arrival"):
        info = dict(rec[4])
        assert info["staleness"] >= 0 and 0 < info["weight"] <= 1
    assert np.isfinite(h_a["final_accuracy"])


def test_async_fedavg_random_subsamples_cohort():
    """fedavg-random under the async policy samples half the membership
    per cloud-fusion window (it used to silently run full
    participation) and only the sampled cohort is dispatched."""
    fed = Federation(FedConfig(**SMALL_KW))
    # homogeneous devices + an explicit cloud period comfortably above
    # the round time, so every window folds its cohort's arrivals (the
    # auto-derived median period would race the cohort by construction)
    fed.topo.capacity[:] = 1e10
    fed.topo.bandwidth[:] = 1e7
    h = fed.run("fedavg-random", global_rounds=2, steps_per_round=2,
                runtime=RuntimeConfig(policy="async", cloud_period_s=10.0))
    tr = h["trace"]
    agg_times = [r[0] for r in tr.of_kind("cloud_agg")]
    assert len(agg_times) == 2
    n, half = SMALL_KW["n_clients"], max(1, SMALL_KW["n_clients"] // 2)
    windows = [(0.0, agg_times[0]), (agg_times[0], agg_times[1])]
    for lo, hi in windows:
        dispatched = {r[2] for r in tr.of_kind("dispatch")
                      if lo <= r[0] < hi}
        assert len(dispatched) == half < n, (lo, hi, dispatched)
    assert np.isfinite(h["final_accuracy"])


def test_async_full_methods_still_dispatch_everyone():
    """Non-subsampling methods keep full participation under async."""
    fed = Federation(FedConfig(**SMALL_KW))
    h = fed.run("fedavg", global_rounds=1, steps_per_round=2,
                runtime=RuntimeConfig(policy="async"))
    tr = h["trace"]
    first_agg = tr.of_kind("cloud_agg")[0][0]
    dispatched = {r[2] for r in tr.of_kind("dispatch") if r[0] < first_agg}
    assert dispatched == set(range(SMALL_KW["n_clients"]))


# ---------------------------------------------------------------------------
# churn-trace edge cases
# ---------------------------------------------------------------------------

def test_make_churn_trace_frac_extremes():
    """churn_frac 0 -> nobody cycles; churn_frac 1 -> the cycling set is
    the whole population (some clients may still draw a first on-dwell
    past the horizon and show zero outages)."""
    none = make_churn_trace(6, 500.0, churn_frac=0.0, seed=2)
    assert all(iv.size == 0 for iv in none.offline)
    assert all(none.is_online(n, t) for n in range(6)
               for t in (0.0, 250.0, 1e6))
    everyone = make_churn_trace(6, 2000.0, mean_on_s=20.0, mean_off_s=10.0,
                                churn_frac=1.0, seed=2)
    assert sum(iv.size > 0 for iv in everyone.offline) == 6
    # intervals are sorted, non-overlapping, and start inside the horizon
    for iv in everyone.offline:
        assert (iv[:, 0] < 2000.0).all()
        assert (iv[:, 1] > iv[:, 0]).all()
        assert (iv[1:, 0] >= iv[:-1, 1]).all()


def test_churn_interval_boundaries_are_half_open():
    """[start, end) semantics exactly at the endpoints, including an
    interval that ends exactly at the horizon."""
    tr = ChurnTrace([np.array([[5.0, 10.0]])], horizon_s=10.0)
    assert tr.is_online(0, 4.999999)
    assert not tr.is_online(0, 5.0)          # start is inclusive
    assert not tr.is_online(0, 9.999999)
    assert tr.is_online(0, 10.0)             # end is exclusive == horizon
    assert tr.next_online(0, 5.0) == 10.0
    assert tr.next_online(0, 10.0) == 10.0   # already online: no-op
    # work dispatched exactly at the outage start waits it out entirely
    assert tr.finish_time(0, 5.0, 1.0) == pytest.approx(11.0)


def test_churn_outage_straddling_horizon_is_honored():
    """An interval generated before but ending after ``horizon_s`` keeps
    pausing work past the horizon — always-on-beyond-horizon applies to
    clients with no remaining intervals, not mid-outage ones."""
    tr = ChurnTrace([np.array([[8.0, 15.0]])], horizon_s=10.0)
    assert not tr.is_online(0, 12.0)
    assert tr.next_online(0, 12.0) == 15.0
    assert tr.finish_time(0, 7.0, 2.0) == pytest.approx(16.0)


def test_churn_all_offline_beyond_horizon_recovers():
    """Once every trace interval is exhausted, clients are always-on:
    the sync barrier can always make progress after the horizon."""
    tr = ChurnTrace([np.array([[0.0, 30.0]]),
                     np.array([[0.0, 40.0]])], horizon_s=30.0)
    assert not tr.is_online(0, 10.0) and not tr.is_online(1, 10.0)
    assert tr.next_online(0, 10.0) == 30.0
    assert tr.next_online(1, 35.0) == 40.0
    assert tr.is_online(0, 50.0) and tr.is_online(1, 50.0)
    assert tr.finish_time(0, 50.0, 3.0) == pytest.approx(53.0)
    # a fully-offline-at-dispatch cohort still finishes: work starts at
    # the first rejoin
    assert tr.finish_time(1, 0.0, 2.0) == pytest.approx(42.0)
