"""Mesh-sharded engine: sharded-vs-unsharded parity, placement, donation.

Methodology mirrors ``tests/test_engine.py``: partitioned compilation
perturbs f32 bits at partition boundaries (measured ~1e-6 after a single
local step on 8 forced host devices), and the split-model gradient map
is chaotic (parameter-Lipschitz ~1e5), so f32 trajectories under real
multi-device sharding diverge *by design*.  Multi-device trajectory
parity therefore runs in x64 with a small lr (discrepancies stay at the
1e-12 level and trajectories stay glued), while the f32 golden history
pins the mesh *code path* — fused cross-group dispatch, shard-multiple
cohort padding, NamedSharding placement — on a 1-device mesh, where
placement is bitwise-inert.

Run single-device these tests cover the mesh path degenerately; the CI
``multi-device`` job re-runs them under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` where the
leading client axis really splits 8 ways.
"""
import json
import os

import jax
import numpy as np
import pytest

import golden_env
from repro.federation.engine import (bucket_size, donate_buffers,
                                     is_client_map, placement_platform)
from repro.federation.simulation import FedConfig, Federation
from repro.launch.mesh import client_axes, make_federation_mesh

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "bert_parity.json")
N_DEV = len(jax.devices())

# same chaos-safe configuration as tests/test_engine.py
PARITY_KW = dict(n_clients=6, n_edges=2, alpha=0.2, poisoned=(4,),
                 total_examples=300, probe_q=8, local_warmup_steps=2,
                 lr=1e-4, layers=4, t_rounds=1, batch_size=16,
                 dtype="float64", seed=0)
# smaller causal-LM variant (second registered model family)
PARITY_KW_LM = dict(PARITY_KW, model="llama3-8b", n_clients=4,
                    total_examples=200)


def _max_tree_diff(a, b):
    """Works across placements: pulls both trees to host first."""
    return max(float(np.abs(np.asarray(x) - np.asarray(y)).max())
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


# ---------------------------------------------------------------------------
# helpers: bucket sizing, client-map detection, donation gating
# ---------------------------------------------------------------------------

def test_bucket_size_shard_multiples():
    assert bucket_size(5) == 5                 # unchanged without a mesh
    assert bucket_size(5, 1) == 5
    assert bucket_size(5, 8) == 8              # next ladder size % 8 == 0
    assert bucket_size(9, 8) == 16
    assert bucket_size(17, 8) == 24
    assert bucket_size(5, 3) == 6
    assert bucket_size(65, 8) == 72            # beyond the ladder: next
    assert bucket_size(65, 6) == 66            # multiple of n itself
    for mult in (2, 3, 4, 8):
        for n in (1, 7, 33, 100):
            s = bucket_size(n, mult)
            assert s >= n and s % mult == 0


def test_is_client_map_distinguishes_lora_trees():
    assert is_client_map({0: "t", 3: "t"})
    # fedavg-random cohorts come out of rng.choice as numpy ints
    assert is_client_map({np.int64(2): "t", np.int32(5): "t"})
    assert not is_client_map({"q_a": 1})       # LoRA pytree node
    assert not is_client_map({True: 1})
    assert not is_client_map({})
    assert not is_client_map([1, 2])


def test_group_steps_client_map_both_backends():
    """group_steps' documented {client: tree} theta form works on both
    backends and, with every entry the shared tree, matches the
    shared-theta call exactly."""
    from repro.data.pipeline import infinite_batches
    kw = dict(n_clients=3, n_edges=1, total_examples=120, layers=4,
              local_warmup_steps=1, probe_q=8, use_channel=False)
    for backend in ("batched", "reference"):
        fed = Federation(FedConfig(**kw), backend=backend)
        clients = [0, 1, 2]

        def its():
            return {n: infinite_batches(fed.data[n].tokens,
                                        fed.data[n].labels,
                                        fed.fed.batch_size, seed=n)
                    for n in clients}

        r_shared = fed.group_steps(clients, fed.lora0, 1, its())
        r_map = fed.group_steps(clients, {n: fed.lora0 for n in clients},
                                1, its())
        for n in clients:
            assert r_shared[n][1] == r_map[n][1]
            assert _max_tree_diff(r_shared[n][0], r_map[n][0]) == 0.0


def test_donation_gates_on_placement():
    assert not donate_buffers("cpu")
    assert donate_buffers("tpu") and donate_buffers("gpu")
    mesh = make_federation_mesh()
    assert placement_platform(mesh) == mesh.devices.flat[0].platform
    assert placement_platform(None) == jax.default_backend()


def test_engine_donation_decision_matches_backend():
    """The engine's donate flag follows the arrays' actual placement
    (mesh devices when sharding, default backend otherwise)."""
    fed = Federation(FedConfig(n_clients=2, n_edges=1, total_examples=64,
                               layers=4), mesh=make_federation_mesh())
    eng = fed.engine
    assert eng.platform == jax.devices()[0].platform
    assert eng.donate == donate_buffers(eng.platform)
    fed2 = Federation(FedConfig(n_clients=2, n_edges=1, total_examples=64,
                                layers=4))
    assert fed2.engine.donate == donate_buffers(jax.default_backend())


# ---------------------------------------------------------------------------
# constructor validation
# ---------------------------------------------------------------------------

def test_reference_backend_rejects_mesh():
    with pytest.raises(ValueError, match="batched"):
        Federation(FedConfig(n_clients=2, total_examples=64, layers=4),
                   backend="reference", mesh=make_federation_mesh())


def test_engine_rejects_mesh_without_client_axis():
    from jax.sharding import Mesh
    cfg = FedConfig(n_clients=2, n_edges=1, total_examples=64, layers=4)
    for axes in (("data",), ("pod",)):   # pod-only: production mesh shape
        bad = Mesh(np.asarray(jax.devices()[:1]), axes)
        fed = Federation(cfg, mesh=bad)
        with pytest.raises(ValueError, match="clients"):
            fed.engine


# ---------------------------------------------------------------------------
# golden parity: the mesh code path is bitwise-inert on a 1-device mesh
# ---------------------------------------------------------------------------

def test_sharded_federation_matches_prerefactor_golden():
    """Fused cross-group dispatch + shard-multiple padding + NamedSharding
    placement are bitwise-inert on a 1-device mesh: the sharded history
    equals a same-environment unsharded run exactly, and — single-device,
    where the committed record's environment is reproduced — the
    pre-refactor golden history at f32/1e-9.  (Forcing multiple host
    devices changes CPU f32 bits globally, sharded or not, so the golden
    anchor only binds at one device.)"""
    gold = json.load(open(GOLDEN))
    kw = dict(gold["config"])
    if "bert_layers" in kw:
        kw["layers"] = kw.pop("bert_layers")   # golden predates the rename
    kw["poisoned"] = tuple(kw["poisoned"])
    run_kw = dict(global_rounds=gold["run"]["global_rounds"],
                  steps_per_round=gold["run"]["steps_per_round"])
    fed = Federation(FedConfig(**kw), backend="batched",
                     mesh=make_federation_mesh(1))
    h = fed.run(gold["run"]["method"], **run_kw)
    fu = Federation(FedConfig(**kw), backend="batched")
    hu = fu.run(gold["run"]["method"], **run_kw)
    np.testing.assert_array_equal(h["loss"], hu["loss"])
    np.testing.assert_array_equal(h["accuracy"], hu["accuracy"])
    np.testing.assert_array_equal(h["delta"], hu["delta"])
    assert _max_tree_diff(fed.last_theta, fu.last_theta) == 0.0
    if N_DEV == 1:
        # float-precision only in the golden's recording environment;
        # a drifted container falls back to the same tolerance band as
        # tests/test_split_api.py (see tests/golden_env.py)
        strict = golden_env.matches(gold.get("env"))
        rtol, atol = (0, 1e-9) if strict else (0.05, 0.1)
        np.testing.assert_allclose(h["loss"], gold["loss"], rtol=rtol,
                                   atol=atol)
        np.testing.assert_allclose(h["accuracy"], gold["accuracy"],
                                   rtol=rtol, atol=atol)
        np.testing.assert_allclose(h["delta"], gold["delta"], rtol=rtol,
                                   atol=atol)
    assert h["round"] == gold["round"]


@pytest.mark.parametrize("method", ["elsa", "fedavg-random"])
def test_fused_dispatch_bitwise_inert_multi_round(method):
    """The 1-device-mesh fused path stays bitwise-identical with
    t_rounds > 1 (loss recording order is group-major like the
    per-group path) and with numpy-int cohorts (fedavg-random samples
    clients via rng.choice)."""
    kw = dict(n_clients=6, n_edges=2, alpha=0.2, poisoned=(4,),
              total_examples=300, probe_q=8, local_warmup_steps=2,
              layers=4, t_rounds=2, batch_size=16, seed=0)
    fu = Federation(FedConfig(**kw), backend="batched")
    hu = fu.run(method, global_rounds=1, steps_per_round=2)
    fs = Federation(FedConfig(**kw), backend="batched",
                    mesh=make_federation_mesh(1))
    hs = fs.run(method, global_rounds=1, steps_per_round=2)
    np.testing.assert_array_equal(hs["loss"], hu["loss"])
    np.testing.assert_array_equal(hs["accuracy"], hu["accuracy"])
    for n in range(kw["n_clients"]):
        np.testing.assert_array_equal(hs["client_losses"][n],
                                      hu["client_losses"][n])
    assert _max_tree_diff(fs.last_theta, fu.last_theta) == 0.0


# ---------------------------------------------------------------------------
# x64 parity: real multi-device sharding computes the same math
# ---------------------------------------------------------------------------

def _assert_sharded_parity(kw, method="elsa", rounds=2, steps=2):
    mesh = make_federation_mesh()        # all available devices
    with jax.experimental.enable_x64():
        fu = Federation(FedConfig(**kw), backend="batched")
        hu = fu.run(method, global_rounds=rounds, steps_per_round=steps)
        fs = Federation(FedConfig(**kw), backend="batched", mesh=mesh)
        hs = fs.run(method, global_rounds=rounds, steps_per_round=steps)
    assert abs(hu["final_accuracy"] - hs["final_accuracy"]) <= 1e-4
    for n in range(kw["n_clients"]):
        a = np.asarray(hu["client_losses"][n])
        b = np.asarray(hs["client_losses"][n])
        assert a.shape == b.shape
        if a.size:
            assert np.abs(a - b).max() <= 1e-5, f"client {n}"
    assert _max_tree_diff(fu.last_theta, fs.last_theta) <= 1e-5
    return fs


def test_sharded_matches_unsharded_x64_bert():
    fs = _assert_sharded_parity(PARITY_KW)
    assert fs.engine.n_shards == N_DEV


def test_sharded_matches_unsharded_x64_causal_lm():
    _assert_sharded_parity(PARITY_KW_LM, method="fedavg", rounds=1)


def test_sharded_fedprox_matches_unsharded_x64():
    """The replicated FedProx anchor broadcasts against sharded stacks."""
    _assert_sharded_parity(PARITY_KW, method="fedprox", rounds=1)


# ---------------------------------------------------------------------------
# placement: arrays really shard across the mesh
# ---------------------------------------------------------------------------

@pytest.mark.skipif(N_DEV < 2, reason="needs >= 2 devices (run under "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_sharded_round_places_arrays_on_all_devices():
    mesh = make_federation_mesh()
    fed = Federation(FedConfig(n_clients=8, n_edges=2, total_examples=320,
                               layers=4, local_warmup_steps=2, probe_q=8),
                     mesh=mesh)
    assert fed.engine.n_shards == N_DEV
    h = fed.run("fedavg", global_rounds=1, steps_per_round=2)
    assert np.isfinite(h["loss"]).all()
    # the aggregated theta came from mesh-resident shards
    leaf = jax.tree_util.tree_leaves(fed.last_theta)[0]
    assert leaf.sharding.device_set == set(mesh.devices.flat)
    # frozen params were replicated up front, not sharded
    froz = jax.tree_util.tree_leaves(fed.engine.frozen)[0]
    assert froz.sharding.is_fully_replicated


@pytest.mark.skipif(N_DEV < 2, reason="needs >= 2 devices")
def test_pod_mesh_runs():
    """("pod", "clients") meshes shard over the composite axes."""
    mesh = make_federation_mesh(pods=2)
    assert client_axes(mesh) == ("pod", "clients")
    fed = Federation(FedConfig(n_clients=4, n_edges=2, total_examples=160,
                               layers=4, local_warmup_steps=2, probe_q=8),
                     mesh=mesh)
    assert fed.engine.n_shards == N_DEV
    h = fed.run("fedavg", global_rounds=1, steps_per_round=2)
    assert np.isfinite(h["loss"]).all()


# ---------------------------------------------------------------------------
# event-driven runtime over the sharded engine
# ---------------------------------------------------------------------------

def test_runtime_schedulers_run_sharded():
    """Every scheduler's dispatches route through the sharded engine
    (cohort padding keeps compiles bounded; placement is invisible to
    the event loop)."""
    from repro.runtime import RuntimeConfig
    mesh = make_federation_mesh()
    for policy in ("sync", "deadline"):
        fed = Federation(FedConfig(n_clients=6, n_edges=2,
                                   total_examples=240, layers=4,
                                   local_warmup_steps=2, probe_q=8),
                         mesh=mesh)
        h = fed.run("fedavg", global_rounds=1, steps_per_round=2,
                    runtime=RuntimeConfig(policy=policy))
        assert h["policy"] == policy
        assert np.isfinite(h["loss"]).all()
        assert fed.engine.n_shards == N_DEV
