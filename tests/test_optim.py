"""Optimizer substrate tests on a quadratic bowl."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import SGD, AdamW, FedAMS, FedProx
from repro.optim.schedules import cosine_decay, warmup_cosine


def _quad_target():
    target = {"a": jnp.array([1.0, -2.0]), "b": jnp.array(3.0)}
    def loss(p):
        return sum(jnp.sum((x - t) ** 2)
                   for x, t in zip(jax.tree_util.tree_leaves(p),
                                   jax.tree_util.tree_leaves(target)))
    return target, loss


def _run(opt, steps=200):
    target, loss = _quad_target()
    params = jax.tree_util.tree_map(jnp.zeros_like, target)
    state = opt.init(params)
    g = jax.grad(loss)
    for _ in range(steps):
        params, state = opt.update(params, g(params), state)
    return float(loss(params))


def test_sgd_converges():
    assert _run(SGD(lr=0.1)) < 1e-6


def test_sgd_momentum_converges():
    assert _run(SGD(lr=0.05, momentum=0.9)) < 1e-6


def test_adamw_converges():
    assert _run(AdamW(lr=0.1), steps=400) < 1e-3


def test_fedprox_stays_near_anchor():
    target, loss = _quad_target()
    opt = FedProx(lr=0.1, mu=10.0)   # strong proximal pull to the origin
    params = jax.tree_util.tree_map(jnp.zeros_like, target)
    state = opt.init(params)
    g = jax.grad(loss)
    for _ in range(200):
        params, state = opt.update(params, g(params), state)
    # with mu=10 and 2*(x-t) gradient: fixed point = 2t/(2+mu)
    np.testing.assert_allclose(np.asarray(params["a"]),
                               np.asarray(2 * target["a"] / 12.0), atol=1e-3)


def test_fedams_server_update():
    target, loss = _quad_target()
    opt = FedAMS(lr=0.5)
    params = jax.tree_util.tree_map(jnp.zeros_like, target)
    state = opt.init(params)
    for _ in range(300):
        # pseudo-gradient = old - new where "new" is one SGD step
        g = jax.grad(loss)(params)
        pseudo = jax.tree_util.tree_map(lambda gg: 0.1 * gg, g)
        params, state = opt.update(params, pseudo, state)
    assert _quad_loss_value(params, target) < 0.1


def _quad_loss_value(p, target):
    return float(sum(jnp.sum((x - t) ** 2)
                     for x, t in zip(jax.tree_util.tree_leaves(p),
                                     jax.tree_util.tree_leaves(target))))


def test_schedules_shapes():
    cd = cosine_decay(100)
    assert float(cd(jnp.array(0))) == 1.0
    assert abs(float(cd(jnp.array(100))) - 0.1) < 1e-6
    wc = warmup_cosine(10, 110)
    assert float(wc(jnp.array(0))) == 0.0
    assert abs(float(wc(jnp.array(10))) - 1.0) < 0.1
