"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ssop as ssop_mod
from repro.core.fingerprint import fingerprint, kl_gaussian, sym_kl
from repro.core.sketch import _median, compress, decompress, make_plan
from repro.core.splitting import SplitPolicy, split_for_client
from repro.core.aggregation import fedavg

SETTINGS = dict(max_examples=20, deadline=None)


@settings(**SETTINGS)
@given(st.integers(2, 16), st.integers(0, 2 ** 31 - 1))
def test_random_orthogonal_is_orthogonal(r, seed):
    v = ssop_mod.random_orthogonal(r, seed)
    np.testing.assert_allclose(np.asarray(v.T @ v), np.eye(r), atol=1e-4)


@settings(**SETTINGS)
@given(st.integers(1, 8), st.integers(0, 1000), st.integers(8, 48))
def test_ssop_inverse_exact(r, seed, d):
    r = min(r, d)
    rng = np.random.default_rng(seed)
    j = jnp.asarray(rng.standard_normal((20, d)), jnp.float32)
    so = ssop_mod.make_ssop(j, r, "salt", seed)
    h = jnp.asarray(rng.standard_normal((5, d)), jnp.float32)
    back = ssop_mod.apply_ssop_inverse(ssop_mod.apply_ssop(h, so), so)
    np.testing.assert_allclose(np.asarray(back), np.asarray(h), atol=2e-4)


@settings(**SETTINGS)
@given(st.integers(0, 500))
def test_ssop_norm_preserving(seed):
    rng = np.random.default_rng(seed)
    j = jnp.asarray(rng.standard_normal((30, 32)), jnp.float32)
    so = ssop_mod.make_ssop(j, 6, "s", seed)
    h = jnp.asarray(rng.standard_normal((7, 32)), jnp.float32)
    out = ssop_mod.apply_ssop(h, so)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(out), axis=-1),
        np.linalg.norm(np.asarray(h), axis=-1), rtol=1e-4)


@settings(**SETTINGS)
@given(st.integers(3, 9), st.integers(2, 6), st.integers(0, 100))
def test_median_network_matches_numpy(y, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((y, d)), jnp.float32)
    got = np.asarray(_median(x, axis=0))
    np.testing.assert_allclose(got, np.median(np.asarray(x), axis=0),
                               atol=1e-6)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 100))
def test_sketch_unbiased_over_plans(seed):
    """E_plan[decompress(compress(h))] ≈ h (count-sketch unbiasedness).

    Y=1 (mean == median) so the estimator is exactly unbiased; per-plan
    std with 4 colliding dims is ~1.7, so the MEAN error over n plans is
    bounded statistically, not tightly."""
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.standard_normal((1, 32)), jnp.float32)
    est = np.zeros((1, 32))
    n = 600
    for i in range(n):
        plan = make_plan(32, 1, 8, seed=seed * 1000 + i)
        est += np.asarray(decompress(compress(h, plan), plan))
    # per-coord std ≈ sqrt(3)/sqrt(600) ≈ 0.07; 5-sigma over 32 coords
    err = np.abs(est / n - np.asarray(h)).max()
    assert err < 0.40, err


@settings(**SETTINGS)
@given(st.floats(1e6, 1e12), st.floats(1e5, 1e9))
def test_split_always_valid(h, bw):
    pol = SplitPolicy(num_blocks=12, o_fix=2, p_min=1, p_max=6)
    p, q, o = split_for_client(h, bw, 1e12, 1e9, pol)
    assert p + q + o == 12 and 1 <= p <= 6 and q >= 4 and o == 2


@settings(**SETTINGS)
@given(st.lists(st.floats(0.1, 10.0), min_size=2, max_size=5))
def test_fedavg_convexity(weights):
    """FedAvg output lies within the convex hull of inputs."""
    trees = [{"w": jnp.full(3, float(i))} for i in range(len(weights))]
    out = fedavg(trees, weights)
    w = np.asarray(out["w"])
    assert (w >= 0 - 1e-5).all() and (w <= len(weights) - 1 + 1e-5).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 50))
def test_kl_nonnegative(seed):
    rng = np.random.default_rng(seed)
    a = fingerprint(jnp.asarray(rng.standard_normal((40, 6)), jnp.float32))
    b = fingerprint(jnp.asarray(
        rng.standard_normal((40, 6)) * 2 + 1, jnp.float32))
    assert float(kl_gaussian(a, b)) >= -1e-4
    assert float(sym_kl(a, b)) >= -1e-4
