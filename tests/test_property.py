"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ssop as ssop_mod
from repro.core import aggregation as agg
from repro.core.fingerprint import fingerprint, kl_gaussian, sym_kl
from repro.core.sketch import _median, compress, decompress, make_plan
from repro.core.splitting import SplitPolicy, split_for_client
from repro.core.aggregation import fedavg
from repro.optim import clip_by_global_norm, global_norm

SETTINGS = dict(max_examples=20, deadline=None)


@settings(**SETTINGS)
@given(st.integers(2, 16), st.integers(0, 2 ** 31 - 1))
def test_random_orthogonal_is_orthogonal(r, seed):
    v = ssop_mod.random_orthogonal(r, seed)
    np.testing.assert_allclose(np.asarray(v.T @ v), np.eye(r), atol=1e-4)


@settings(**SETTINGS)
@given(st.integers(1, 8), st.integers(0, 1000), st.integers(8, 48))
def test_ssop_inverse_exact(r, seed, d):
    r = min(r, d)
    rng = np.random.default_rng(seed)
    j = jnp.asarray(rng.standard_normal((20, d)), jnp.float32)
    so = ssop_mod.make_ssop(j, r, "salt", seed)
    h = jnp.asarray(rng.standard_normal((5, d)), jnp.float32)
    back = ssop_mod.apply_ssop_inverse(ssop_mod.apply_ssop(h, so), so)
    np.testing.assert_allclose(np.asarray(back), np.asarray(h), atol=2e-4)


@settings(**SETTINGS)
@given(st.integers(0, 500))
def test_ssop_norm_preserving(seed):
    rng = np.random.default_rng(seed)
    j = jnp.asarray(rng.standard_normal((30, 32)), jnp.float32)
    so = ssop_mod.make_ssop(j, 6, "s", seed)
    h = jnp.asarray(rng.standard_normal((7, 32)), jnp.float32)
    out = ssop_mod.apply_ssop(h, so)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(out), axis=-1),
        np.linalg.norm(np.asarray(h), axis=-1), rtol=1e-4)


@settings(**SETTINGS)
@given(st.integers(3, 9), st.integers(2, 6), st.integers(0, 100))
def test_median_network_matches_numpy(y, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((y, d)), jnp.float32)
    got = np.asarray(_median(x, axis=0))
    np.testing.assert_allclose(got, np.median(np.asarray(x), axis=0),
                               atol=1e-6)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 100))
def test_sketch_unbiased_over_plans(seed):
    """E_plan[decompress(compress(h))] ≈ h (count-sketch unbiasedness).

    Y=1 (mean == median) so the estimator is exactly unbiased; per-plan
    std with 4 colliding dims is ~1.7, so the MEAN error over n plans is
    bounded statistically, not tightly."""
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.standard_normal((1, 32)), jnp.float32)
    est = np.zeros((1, 32))
    n = 600
    for i in range(n):
        plan = make_plan(32, 1, 8, seed=seed * 1000 + i)
        est += np.asarray(decompress(compress(h, plan), plan))
    # per-coord std ≈ sqrt(3)/sqrt(600) ≈ 0.07; 5-sigma over 32 coords
    err = np.abs(est / n - np.asarray(h)).max()
    assert err < 0.40, err


@settings(**SETTINGS)
@given(st.floats(1e6, 1e12), st.floats(1e5, 1e9))
def test_split_always_valid(h, bw):
    pol = SplitPolicy(num_blocks=12, o_fix=2, p_min=1, p_max=6)
    p, q, o = split_for_client(h, bw, 1e12, 1e9, pol)
    assert p + q + o == 12 and 1 <= p <= 6 and q >= 4 and o == 2


@settings(**SETTINGS)
@given(st.lists(st.floats(0.1, 10.0), min_size=2, max_size=5))
def test_fedavg_convexity(weights):
    """FedAvg output lies within the convex hull of inputs."""
    trees = [{"w": jnp.full(3, float(i))} for i in range(len(weights))]
    out = fedavg(trees, weights)
    w = np.asarray(out["w"])
    assert (w >= 0 - 1e-5).all() and (w <= len(weights) - 1 + 1e-5).all()


# ---------------------------------------------------------------------------
# global-norm gradient clipping
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(0, 500), st.floats(1e-3, 10.0))
def test_clip_norm_never_exceeds_cap(seed, cap):
    rng = np.random.default_rng(seed)
    g = {"a": jnp.asarray(rng.standard_normal((3, 5)), jnp.float32),
         "b": {"c": jnp.asarray(rng.standard_normal(7) * 10, jnp.float32)}}
    c = clip_by_global_norm(g, cap)
    assert float(global_norm(c)) <= cap * (1 + 1e-5)


@settings(**SETTINGS)
@given(st.integers(0, 500))
def test_clip_preserves_direction_and_noops_under_cap(seed):
    rng = np.random.default_rng(seed)
    g = {"a": jnp.asarray(rng.standard_normal(12), jnp.float32)}
    n = float(global_norm(g))
    # under the cap: exact identity (scale 1.0)
    under = clip_by_global_norm(g, n * 2.0)
    np.testing.assert_array_equal(np.asarray(under["a"]), np.asarray(g["a"]))
    # over the cap: same direction, norm == cap
    over = clip_by_global_norm(g, n / 3.0)
    cos = float(jnp.vdot(over["a"], g["a"])
                / (jnp.linalg.norm(over["a"]) * jnp.linalg.norm(g["a"])))
    assert abs(cos - 1.0) < 1e-5
    np.testing.assert_allclose(float(global_norm(over)), n / 3.0, rtol=1e-5)


def test_clip_zero_grads_safe():
    z = {"a": jnp.zeros((4, 4)), "b": jnp.zeros(3)}
    c = clip_by_global_norm(z, 1.0)
    for leaf in jax.tree_util.tree_leaves(c):
        assert bool(jnp.isfinite(leaf).all()) and float(jnp.abs(leaf).max()) == 0.0


# ---------------------------------------------------------------------------
# product-space (weight-delta) adapter aggregation
# ---------------------------------------------------------------------------

def _factor_tree(seed, L=2, d=6, r=2, heads=2, hd=3, a=None):
    rng = np.random.default_rng(seed)
    return {"blocks": {"attn": {
        "q_a": (a if a is not None else
                jnp.asarray(rng.standard_normal((L, d, r)), jnp.float32)),
        "q_b": jnp.asarray(rng.standard_normal((L, r, heads, hd)),
                           jnp.float32),
    }}, "head": {"w": jnp.asarray(rng.standard_normal((d, 4)), jnp.float32)}}


def _delta(tree):
    return agg.tree_to_deltas(tree)["blocks"]["attn"]["q_dw"]


def test_product_aggregation_single_client_identity():
    """n=1 reduces to the client's tree exactly (delta and factors)."""
    t = _factor_tree(0)
    out = agg.product_fedavg([t], [3.0])
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(**SETTINGS)
@given(st.integers(0, 200),
       st.lists(st.floats(0.1, 10.0), min_size=2, max_size=4))
def test_product_aggregation_shared_a_exact_mean(seed, weights):
    """Clients sharing A (heterogeneity only in B): the aggregated
    delta IS the weighted-mean delta (factor averaging is exact there
    and the pinv correction must not disturb it)."""
    a = jnp.asarray(np.random.default_rng(seed).standard_normal((2, 6, 2)),
                    jnp.float32)
    trees = [_factor_tree(seed + 1 + i, a=a) for i in range(len(weights))]
    out = agg.product_fedavg(trees, weights)
    w = np.asarray(weights) / np.sum(weights)
    want = sum(wi * _delta(t) for wi, t in zip(w, trees))
    np.testing.assert_allclose(np.asarray(_delta(out)), np.asarray(want),
                               atol=1e-4)


@settings(**SETTINGS)
@given(st.integers(0, 200),
       st.lists(st.floats(0.1, 10.0), min_size=2, max_size=4))
def test_product_aggregation_never_worse_than_factor(seed, weights):
    """The anchored correction is a projection onto col(mean A), so the
    implied delta's error against the true weighted-mean delta is <=
    factor averaging's error, and non-pair leaves (the head) match the
    plain weighted mean bitwise."""
    trees = [_factor_tree(seed + i) for i in range(len(weights))]
    fac = agg.aggregate_adapters(trees, weights, mode="factor")
    pro = agg.aggregate_adapters(trees, weights, mode="product")
    w = np.asarray(weights) / np.sum(weights)
    want = sum(wi * _delta(t) for wi, t in zip(w, trees))
    err_f = float(jnp.linalg.norm(_delta(fac) - want))
    err_p = float(jnp.linalg.norm(_delta(pro) - want))
    assert err_p <= err_f + 1e-5
    np.testing.assert_array_equal(np.asarray(pro["head"]["w"]),
                                  np.asarray(fac["head"]["w"]))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 50))
def test_kl_nonnegative(seed):
    rng = np.random.default_rng(seed)
    a = fingerprint(jnp.asarray(rng.standard_normal((40, 6)), jnp.float32))
    b = fingerprint(jnp.asarray(
        rng.standard_normal((40, 6)) * 2 + 1, jnp.float32))
    assert float(kl_gaussian(a, b)) >= -1e-4
    assert float(sym_kl(a, b)) >= -1e-4


# ---------------------------------------------------------------------------
# checkpoint wire format: arbitrary mixed-dtype pytrees roundtrip exactly
# ---------------------------------------------------------------------------

_DTYPES = (np.float32, np.int32, np.bool_, "bfloat16", np.float64,
           np.int64)


def _leaf_strategy():
    def build(draw_tuple):
        dt, shape, seed = draw_tuple
        rng = np.random.default_rng(seed)
        if dt is np.bool_:
            return rng.random(shape) > 0.5
        if dt in (np.int32, np.int64):
            return rng.integers(-1000, 1000, shape).astype(dt)
        if dt == "bfloat16":
            import ml_dtypes
            return rng.standard_normal(shape).astype(ml_dtypes.bfloat16)
        return rng.standard_normal(shape).astype(dt)

    return st.tuples(
        st.sampled_from(_DTYPES),
        st.tuples(st.integers(0, 3), st.integers(1, 4)),
        st.integers(0, 2 ** 31 - 1),
    ).map(build)


def _tree_strategy():
    scalar = st.one_of(st.none(), st.booleans(),
                       st.integers(-10**6, 10**6),
                       st.floats(allow_nan=False, allow_infinity=False,
                                 width=64),
                       st.text(max_size=8))
    return st.recursive(
        st.one_of(_leaf_strategy(), scalar),
        lambda kids: st.one_of(
            st.lists(kids, max_size=3),
            st.lists(kids, max_size=3).map(tuple),
            st.dictionaries(st.text(alphabet="abcxyz", min_size=1,
                                    max_size=6), kids, max_size=3)),
        max_leaves=12)


@settings(max_examples=25, deadline=None)
@given(tree=_tree_strategy())
def test_checkpoint_roundtrip_mixed_dtype_trees(tmp_path_factory, tree):
    """save -> restore is the identity on nested dict/list/tuple trees
    over f32/f64/i32/i64/bool/bfloat16 leaves: same treedef (tuples stay
    tuples), same dtypes, same bits."""
    from repro.checkpoint import restore, save, tree_equal
    p = str(tmp_path_factory.mktemp("ckpt") / "t.msgpack")
    save(p, tree)
    out = restore(p)
    assert tree_equal(tree, out)


# ---------------------------------------------------------------------------
# population registry: gather/scatter round-trips
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(5, 60), st.integers(1, 16), st.data())
def test_registry_scatter_preserves_untouched_rows(n, shard_rows, data):
    """A cohort scatter (scalar columns + the sharded adapter column)
    touches exactly its rows: every non-cohort row reads back bitwise
    identical, for any population size / shard geometry / cohort."""
    from repro.population import ClientRegistry

    reg = ClientRegistry(n, adapter_dim=3, shard_rows=shard_rows, seed=1)
    k = data.draw(st.integers(1, n))
    ids = np.asarray(data.draw(st.lists(st.integers(0, n - 1),
                                        min_size=k, max_size=k,
                                        unique=True)), np.int64)
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31 - 1)))
    # pre-populate some rows so "untouched" is not just "still zero"
    pre = np.arange(0, n, 2, dtype=np.int64)
    reg.scatter(pre, trust=rng.random(len(pre)))
    reg.scatter_adapters(pre, rng.standard_normal((len(pre), 3))
                         .astype(np.float32))
    before = {name: col.copy() for name, col in reg.columns.items()}
    adapters_before = reg.gather_adapters(np.arange(n))

    reg.scatter(ids, trust=rng.random(k),
                participations=rng.integers(0, 5, k))
    reg.scatter_adapters(ids, rng.standard_normal((k, 3))
                         .astype(np.float32))

    others = np.setdiff1d(np.arange(n), ids)
    for name in reg.columns:
        np.testing.assert_array_equal(reg.columns[name][others],
                                      before[name][others])
    np.testing.assert_array_equal(reg.gather_adapters(others),
                                  adapters_before[others])


@settings(**SETTINGS)
@given(st.integers(1, 40), st.integers(1, 13), st.integers(0, 2 ** 31 - 1))
def test_registry_state_roundtrip_bitwise(n, shard_rows, seed):
    """state() -> load_state() is the identity on every column and every
    allocated adapter shard, for any geometry."""
    from repro.population import ClientRegistry

    rng = np.random.default_rng(seed)
    reg = ClientRegistry(n, adapter_dim=2, shard_rows=shard_rows,
                         seed=seed)
    k = int(rng.integers(1, n + 1))
    ids = rng.choice(n, k, replace=False)
    reg.scatter(ids, trust=rng.random(k), draws=rng.integers(0, 99, k))
    reg.scatter_adapters(ids, rng.standard_normal((k, 2))
                         .astype(np.float32))
    out = ClientRegistry(n, adapter_dim=2, shard_rows=shard_rows,
                         seed=seed)
    out.load_state(reg.state())
    for name in reg.columns:
        np.testing.assert_array_equal(out.columns[name],
                                      reg.columns[name])
    assert out.allocated_shards == reg.allocated_shards
    np.testing.assert_array_equal(out.gather_adapters(np.arange(n)),
                                  reg.gather_adapters(np.arange(n)))


@settings(**SETTINGS)
@given(st.integers(2, 300), st.data())
def test_cohort_sampler_valid_and_stateless(n, data):
    """Every strategy returns k sorted distinct in-range ids, and the
    round-g cohort is a pure function of (seed, g)."""
    from repro.population import (ClientRegistry, CohortSampler,
                                  PopulationConfig)

    k = data.draw(st.integers(1, n))
    g = data.draw(st.integers(0, 10 ** 6))
    seed = data.draw(st.integers(0, 10 ** 6))
    strategy = data.draw(st.sampled_from(["uniform", "round-robin"]))

    def sample():
        cfg = PopulationConfig(registered=n, seed=seed, strategy=strategy)
        return CohortSampler(ClientRegistry(n), cfg).sample(g, k)

    ids = sample()
    assert ids.shape == (k,) and ids.dtype == np.int64
    assert len(np.unique(ids)) == k
    assert ids.min() >= 0 and ids.max() < n
    assert (np.diff(ids) > 0).all() if k > 1 else True
    np.testing.assert_array_equal(ids, sample())
