"""Serving engine: batched generation, prompt consumption, EOS handling,
and consistency with raw step-by-step decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import zoo
from repro.models.params import init_tree
from repro.serving import ServingEngine

CFG = get_config("qwen2.5-3b").reduced()


@pytest.fixture(scope="module")
def engine():
    return ServingEngine(CFG, batch_size=3, max_len=48, seed=0)


def test_batched_generation_completes(engine):
    reqs = [engine.submit([1, 2, 3], max_new_tokens=5),
            engine.submit([7, 8], max_new_tokens=4),
            engine.submit([5], max_new_tokens=6)]
    done = engine.run_until_drained()
    assert len(done) == 3
    for r, n in zip(reqs, (5, 4, 6)):
        assert r.done and len(r.output) == n
        assert all(0 <= t < CFG.vocab_size for t in r.output)
    tp = engine.throughput()
    assert tp["tokens_per_s"] > 0 and tp["requests"] == 3


def test_queue_overflow_runs_multiple_batches(engine):
    for _ in range(5):
        engine.submit([1, 2], max_new_tokens=2)
    done = engine.run_until_drained()
    assert len(done) == 5
    assert all(len(r.output) == 2 for r in done)


def test_eos_terminates_early():
    eng = ServingEngine(CFG, batch_size=1, max_len=48, seed=0)
    probe = eng.submit([3, 1, 4], max_new_tokens=10)
    eng.run_until_drained()
    first = probe.output[0]
    # resubmit with that token as EOS: must stop at length 1
    eng2 = ServingEngine(CFG, batch_size=1, max_len=48, seed=0)
    r = eng2.submit([3, 1, 4], max_new_tokens=10, eos_id=first)
    eng2.run_until_drained()
    assert r.output[0] == first and len(r.output) == 1


def test_engine_matches_manual_decode():
    """Engine output == hand-rolled greedy decode over the same model."""
    eng = ServingEngine(CFG, batch_size=1, max_len=48, seed=0)
    prompt = [11, 23, 5, 2]
    r = eng.submit(prompt, max_new_tokens=4)
    eng.run_until_drained()

    model = zoo.get_model(CFG)
    params = init_tree(model.specs(CFG), jax.random.PRNGKey(0), CFG.dtype())
    cache = init_tree(model.cache_specs(CFG, 1, 48), jax.random.PRNGKey(1),
                      CFG.dtype())
    toks = list(prompt)
    out = []
    tok = jnp.asarray([[toks[0]]], jnp.int32)
    for t in range(1, len(prompt) + 4):
        logits, cache = model.decode_step(CFG, params["frozen"],
                                          params["lora"], cache,
                                          {"tokens": tok})
        nxt = int(jnp.argmax(logits[0, -1, :CFG.vocab_size]))
        if t < len(prompt):
            tok = jnp.asarray([[prompt[t]]], jnp.int32)
        else:
            out.append(nxt)
            tok = jnp.asarray([[nxt]], jnp.int32)
            if len(out) == 4:
                break
    assert r.output == out
