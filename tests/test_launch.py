"""Launcher helpers: batch partitioning, ELSA boundaries, mesh factory."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.launch.mesh import (chips, client_axes, data_axes,
                               make_federation_mesh)
from repro.launch.train import batch_pspec, elsa_boundaries, elsa_channel_specs

from conftest import make_abstract_mesh

MESH = make_abstract_mesh((16, 16), ("data", "model"))
MESH3 = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_batch_pspec_divisible():
    assert batch_pspec(MESH, 256) == P("data")
    assert batch_pspec(MESH3, 256) == P(("pod", "data"))


def test_batch_pspec_indivisible_replicates():
    assert batch_pspec(MESH, 1) == P()
    assert batch_pspec(MESH3, 8) == P()


def test_data_axes():
    assert data_axes(MESH) == ("data",)
    assert data_axes(MESH3) == ("pod", "data")


def test_chips():
    assert chips(MESH) == 256
    assert chips(MESH3) == 512


def test_client_axes():
    fm = make_federation_mesh(1)
    assert client_axes(fm) == ("clients",)
    assert client_axes(MESH) == ()           # production mesh: no clients
    assert client_axes(MESH3) == ("pod",)    # pod folds into the stack


def test_make_federation_mesh_defaults_to_all_devices():
    n = len(jax.devices())
    mesh = make_federation_mesh()
    assert tuple(mesh.shape) == ("clients",)
    assert mesh.shape["clients"] == n
    assert chips(mesh) == n


def test_make_federation_mesh_subset_and_validation():
    assert chips(make_federation_mesh(1)) == 1
    with pytest.raises(ValueError):
        make_federation_mesh(len(jax.devices()) + 1)
    with pytest.raises(ValueError):
        make_federation_mesh(0)


def test_make_federation_mesh_pods():
    devs = jax.devices()
    if len(devs) % 2 == 0 and len(devs) >= 2:
        mesh = make_federation_mesh(pods=2)
        assert tuple(mesh.shape) == ("pod", "clients")
        assert mesh.shape["pod"] == 2
        assert chips(mesh) == len(devs)
    with pytest.raises(ValueError):
        make_federation_mesh(1, pods=3)      # 1 device, 3 pods


def test_elsa_boundaries_valid_for_all_archs():
    for arch in ASSIGNED:
        cfg = get_config(arch)
        if cfg.family not in ("dense", "moe"):
            continue
        p, q = elsa_boundaries(cfg)
        n = cfg.num_layers - (cfg.moe.first_dense_layers if cfg.moe else 0)
        assert 1 <= p <= 6
        assert p + q + 2 == n          # o_fix = 2 (label privacy)
        assert q >= 1


def test_elsa_channel_specs_shapes():
    cfg = get_config("llama3-8b")
    specs, z = elsa_channel_specs(cfg, r=16, y=3, rho=2.1)
    d = cfg.d_model
    assert specs["u"].shape == (d, 16)
    assert specs["v"].shape == (16, 16)
    assert specs["bucket"].shape == (3, d)
    assert specs["bucket"].dtype == jnp.int32
    # rho = D / (Y Z) within ~20% of the requested 2.1
    rho = d / (3 * z)
    assert 1.6 < rho < 2.6
