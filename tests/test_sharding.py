"""Sharding-rule tests using AbstractMesh (no devices needed)."""
import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.models import zoo
from repro.models.params import (DEFAULT_RULES, Spec, partition_spec,
                                 tree_pspecs)

from conftest import make_abstract_mesh

MESH = make_abstract_mesh((16, 16), ("data", "model"))
MESH3 = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_divisible_dims_shard():
    s = Spec((4096, 14336), ("embed", "mlp"))
    assert partition_spec(s, MESH) == P(None, "model")


def test_indivisible_dims_replicate():
    # whisper: 12 heads on a 16-way model axis -> replicated
    s = Spec((768, 12, 64), ("embed", "heads", None))
    assert partition_spec(s, MESH) == P()


def test_each_mesh_axis_used_once():
    s = Spec((8, 4096, 32768), ("experts", "embed", "mlp"))
    rules = dict(DEFAULT_RULES)
    rules["experts"] = ("model",)
    # experts=8 not divisible by 16 -> falls through to mlp
    assert partition_spec(s, MESH, rules) == P(None, None, "model")
    rules2 = dict(DEFAULT_RULES)
    rules2["experts"] = ("model",)
    s2 = Spec((160, 5120, 1536), ("experts", "embed", "mlp"))
    # 160 % 16 == 0: experts take 'model'; mlp cannot reuse it
    assert partition_spec(s2, MESH, rules2) == P("model")


def test_batch_composite_axis():
    s = Spec((256, 32768, 8, 128), ("batch", None, "kv_heads", None))
    assert partition_spec(s, MESH3) == P(("pod", "data"))
    s1 = Spec((1, 524288, 8, 128), ("batch", None, "kv_heads", None))
    # batch=1: no data sharding possible
    assert partition_spec(s1, MESH3) == P()


def test_vocab_padding_is_shardable():
    for arch in ASSIGNED:
        cfg = get_config(arch)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size


def test_all_param_specs_have_matching_axes():
    """Every Spec's axes tuple must match its rank (catches drift)."""
    for arch in ASSIGNED:
        cfg = get_config(arch)
        specs = zoo.get_model(cfg).specs(cfg)
        leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, Spec))
        for s in leaves:
            assert len(s.shape) == len(s.axes), (arch, s)


def test_full_model_pspecs_build():
    """tree_pspecs over every full-size arch must not raise."""
    for arch in ASSIGNED:
        cfg = get_config(arch)
        specs = zoo.get_model(cfg).specs(cfg)
        ps = tree_pspecs(specs, MESH3)
        assert jax.tree_util.tree_leaves(ps, is_leaf=lambda x: isinstance(
            x, P)) or True
