"""Pallas kernel validation (interpret=True): shape/dtype sweeps vs the
pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sketch import make_plan, selection_matrices, compress, decompress
from repro.core import ssop as ssop_core
from repro.kernels.count_sketch import ops as cs_ops
from repro.kernels.count_sketch.ref import compress_ref, decompress_ref
from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_bhsd_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.lora import ops as lora_ops
from repro.kernels.lora.ref import lora_matmul_ref
from repro.kernels.ssop import ops as ssop_ops
from repro.kernels.ssop.ref import ssop_apply_ref

TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
       jnp.bfloat16: dict(atol=5e-2, rtol=5e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,bkv,sq,sk,d,causal,window,bq,bk", [
    (8, 2, 128, 128, 64, True, 0, 64, 64),
    (4, 4, 256, 256, 32, True, 64, 128, 64),
    (4, 2, 128, 256, 64, False, 0, 128, 128),
    (2, 1, 64, 512, 128, True, 128, 64, 128),
])
def test_flash_attention_sweep(dtype, bh, bkv, sq, sk, d, causal, window,
                               bq, bk):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (bh, sq, d), dtype)
    k = jax.random.normal(keys[1], (bkv, sk, d), dtype)
    v = jax.random.normal(keys[2], (bkv, sk, d), dtype)
    out = flash_attention_bhsd(q, k, v, causal=causal, window=window,
                               bq=bq, bk=bk)
    ref = attention_bhsd_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_flash_attention_bshd_wrapper():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 128, 8, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 128, 2, 32))
    out = fa_ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    from repro.models.common import gqa_attention
    ref = gqa_attention(q, k, v, causal=True, chunk=4096)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("t,d,y,z", [(64, 256, 3, 32), (128, 512, 5, 64),
                                     (32, 128, 4, 16)])
def test_count_sketch_kernels(t, d, y, z):
    h = jax.random.normal(jax.random.PRNGKey(0), (t, d))
    plan = make_plan(d, y, z, seed=3)
    s = selection_matrices(plan)
    u_k = cs_ops.sketch_compress(h, plan)
    np.testing.assert_allclose(np.asarray(u_k), np.asarray(compress_ref(h, s)),
                               atol=1e-5)
    # kernel compress == core (scatter) compress
    np.testing.assert_allclose(np.asarray(u_k),
                               np.asarray(compress(h, plan, via_matmul=False)),
                               atol=1e-4)
    d_k = cs_ops.sketch_decompress(u_k, plan)
    np.testing.assert_allclose(np.asarray(d_k),
                               np.asarray(decompress_ref(u_k, s)), atol=1e-5)
    np.testing.assert_allclose(np.asarray(d_k),
                               np.asarray(decompress(u_k, plan)), atol=1e-4)


@pytest.mark.parametrize("t,d,r", [(64, 256, 8), (128, 512, 16), (32, 128, 4)])
def test_ssop_kernel(t, d, r):
    j = jax.random.normal(jax.random.PRNGKey(0), (40, d))
    so = ssop_core.make_ssop(j, r, "salt", 5)
    h = jax.random.normal(jax.random.PRNGKey(1), (t, d))
    w = so.v.T - jnp.eye(r)
    out_k = ssop_ops.ssop_apply(h, so.u, so.v)
    np.testing.assert_allclose(np.asarray(out_k),
                               np.asarray(ssop_apply_ref(h, so.u, w)),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(out_k),
                               np.asarray(ssop_core.apply_ssop(h, so)),
                               atol=1e-5)
    # kernel inverse restores exactly
    back = ssop_ops.ssop_apply_inverse(out_k, so.u, so.v)
    np.testing.assert_allclose(np.asarray(back), np.asarray(h), atol=1e-4)


@pytest.mark.parametrize("t,k,o,r", [(64, 128, 256, 8), (128, 256, 128, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_kernel(t, k, o, r, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (t, k), dtype)
    w = (jax.random.normal(ks[1], (k, o)) * 0.05).astype(dtype)
    a = (jax.random.normal(ks[2], (k, r)) * 0.05).astype(dtype)
    b = (jax.random.normal(ks[3], (r, o)) * 0.05).astype(dtype)
    out = lora_ops.lora_matmul(x, w, a, b, 2.0)
    ref = lora_matmul_ref(x, w, a, b, 2.0)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])
