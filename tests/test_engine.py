"""Batched-engine parity vs the sequential reference backend.

Why float64 + small lr: the split-BERT gradient map is chaotic — a 1e-6
relative parameter perturbation changes the eager gradient by ~1e-1
(measured parameter-Lipschitz ~1e5 on the q_b LoRA leaf), and the
count-sketch median's subgradient is discontinuous.  Any fp-level
discrepancy between two compilation strategies (eager per-client loop vs
vmap/scan jit) therefore amplifies by roughly ``lr * 1e5`` per local
step.  Running parity in x64 with a small lr keeps backend discrepancies
at the 1e-12 level where trajectories stay glued for the whole run —
which is exactly what we want to verify: that the batched engine
computes the *same math* as the reference, the one thing a vmap/scan
rewrite can silently get wrong.  At the training lr we additionally
check single-step gradient parity (before chaos can amplify).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sketch import (SketchPlan, channel, compress, decompress,
                               make_plan, selection_matrices)

# small-lr / f64 parity configuration; total_examples=300 gives client 0
# a 14-example dataset so every one of its batches is a ragged, padded one
PARITY_KW = dict(n_clients=6, n_edges=2, alpha=0.2, poisoned=(4,),
                 total_examples=300, probe_q=8, local_warmup_steps=2,
                 lr=1e-4, layers=4, t_rounds=1, batch_size=16,
                 dtype="float64", seed=0)


def _max_tree_diff(a, b):
    return max(float(jnp.abs(x - y).max()) for x, y in
               zip(jax.tree_util.tree_leaves(a),
                   jax.tree_util.tree_leaves(b)))


@pytest.fixture(scope="module")
def x64_feds():
    from repro.federation.simulation import FedConfig, Federation
    with jax.experimental.enable_x64():
        fb = Federation(FedConfig(**PARITY_KW), backend="batched")
        fr = Federation(FedConfig(**PARITY_KW), backend="reference")
        yield fb, fr


def _assert_run_parity(fb, fr, method, rounds=2, steps=2):
    hb = fb.run(method, global_rounds=rounds, steps_per_round=steps)
    hr = fr.run(method, global_rounds=rounds, steps_per_round=steps)
    assert abs(hb["final_accuracy"] - hr["final_accuracy"]) <= 1e-4
    for n in range(fb.fed.n_clients):
        a = np.asarray(hb["client_losses"][n])
        b = np.asarray(hr["client_losses"][n])
        assert a.shape == b.shape
        if a.size:
            assert np.abs(a - b).max() <= 1e-5, f"client {n}"
    # end-of-run theta: the backends reassociate fp differently and the
    # chaotic map amplifies that over rounds*steps local steps, so how
    # far the trajectories sit apart at the end varies with XLA codegen
    # (~2e-5 under the elsa channel here); the single-step parity test
    # below holds the 1e-8-level same-math line
    assert _max_tree_diff(fb.last_theta, fr.last_theta) <= 1e-4


def test_engine_matches_reference_elsa(x64_feds):
    """Full Alg. 1 (clustered, SS-OP∘sketch channel on): batched == ref."""
    with jax.experimental.enable_x64():
        _assert_run_parity(*x64_feds, "elsa")


def test_engine_matches_reference_fedprox(x64_feds):
    """FedProx anchor term vectorizes identically (broadcast anchor)."""
    with jax.experimental.enable_x64():
        _assert_run_parity(*x64_feds, "fedprox")


def test_engine_single_step_parity_at_training_lr(x64_feds):
    """One local step at the real lr: gradient math identical to 1e-8
    (before chaotic trajectory amplification can kick in)."""
    from repro.data.pipeline import infinite_batches
    with jax.experimental.enable_x64():
        fb, fr = x64_feds
        lr0 = fb.fed.lr
        clients = list(range(fb.fed.n_clients))

        def its(f):
            return {n: infinite_batches(f.data[n].tokens, f.data[n].labels,
                                        f.fed.batch_size, seed=777 + n)
                    for n in clients}

        rb = fb.group_steps(clients, fb.lora0, 1, its(fb))
        rr = fr.group_steps(clients, fr.lora0, 1, its(fr))
        for n in clients:
            lb, sb = rb[n]
            lrr, sr = rr[n]
            assert abs(sb - sr) <= 1e-9
            # updates are lr-scaled; compare the implied gradient
            assert _max_tree_diff(lb, lrr) / lr0 <= 1e-6


def test_make_plan_selection_cache_regression():
    """Precomputing the signed-selection tensor on the plan must not
    change compress/decompress/channel outputs (bit-identical)."""
    plan = make_plan(64, 3, 16, seed=5)
    assert plan.selection is not None
    plain = SketchPlan(plan.bucket, plan.sign, plan.z)     # no cache
    assert plain.selection is None
    h = jax.random.normal(jax.random.PRNGKey(2), (7, 5, 64))
    np.testing.assert_array_equal(np.asarray(compress(h, plan)),
                                  np.asarray(compress(h, plain)))
    u = compress(h, plan)
    np.testing.assert_array_equal(np.asarray(decompress(u, plan)),
                                  np.asarray(decompress(u, plain)))
    np.testing.assert_array_equal(np.asarray(channel(h, plan)),
                                  np.asarray(channel(h, plain)))
    # cached tensor == rebuilt tensor, and scatter path stays bit-equal
    np.testing.assert_array_equal(np.asarray(selection_matrices(plan)),
                                  np.asarray(selection_matrices(plain)))
    np.testing.assert_allclose(
        np.asarray(compress(h, plan, via_matmul=False)),
        np.asarray(compress(h, plan)), atol=1e-6)


def test_weighted_loss_padding_matches_unpadded():
    """Zero-weight padded rows contribute exactly nothing to loss/grad."""
    from repro.configs import get_config
    from repro.core.split_training import (Channel, Split, split_loss,
                                           weighted_split_loss)
    from repro.models import bert as bert_mod
    from repro.models.params import init_tree

    cfg = get_config("bert-base").reduced().with_(num_layers=4)
    tree = init_tree(bert_mod.bert_specs(cfg, 4), jax.random.PRNGKey(0),
                     jnp.float32)
    frozen, lora = tree["frozen"], tree["lora"]
    toks = jax.random.randint(jax.random.PRNGKey(1), (9, 12), 0,
                              cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (9,), 0, 4)
    split = Split(1, 1, 2)
    ref = {"tokens": toks, "labels": labels}
    pad_t = jnp.concatenate([toks, jnp.zeros((7, 12), toks.dtype)])
    pad_l = jnp.concatenate([labels, jnp.zeros((7,), labels.dtype)])
    w = jnp.concatenate([jnp.ones(9), jnp.zeros(7)])
    padded = {"tokens": pad_t, "labels": pad_l, "weights": w}

    l_ref, g_ref = jax.value_and_grad(
        lambda lp: split_loss(cfg, frozen, lp, ref, split))(lora)
    l_pad, g_pad = jax.value_and_grad(
        lambda lp: weighted_split_loss(cfg, frozen, lp, padded, split))(lora)
    assert abs(float(l_ref) - float(l_pad)) <= 1e-6
    assert _max_tree_diff(g_ref, g_pad) <= 1e-5
