"""Identity-keyed SS-OP channels and trust attribution
(docs/population.md).

The privacy rotation and the trust EMA must follow the registered
*identity*, never the federation slot it happens to execute in:

1. Property: a client's rotation is invariant under arbitrary slot
   assignments and cohort schedules (seeded-random sweep always runs; a
   hypothesis version runs where hypothesis is installed).
2. Two identities streaming through the same slot across rounds get
   *distinct* rotations; a returning identity gets its original
   rotation bit-exactly after LRU eviction.
3. Straggler attribution: a verdict for an update that completes after
   a cohort swap lands on the pinned dispatch-time identity — the
   slot's new occupant is never credited or blamed (deadline
   ``screen_cohort`` path and the async per-arrival path, plus
   end-to-end scheduler runs).
4. The async scheduler emits ``screening.verdicts`` telemetry counters
   (it was the one screening path that recorded none).
"""
import numpy as np
import pytest

from repro import telemetry as tm
from repro.core.ssop import client_seed, random_orthogonal
from repro.federation.simulation import FedConfig, Federation
from repro.population import PopulationConfig, PopulationRuntime
from repro.runtime import RuntimeConfig

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # container without hypothesis: seeded sweep only
    HAVE_HYPOTHESIS = False

CHAN = dict(n_clients=4, n_edges=2, alpha=5.0, poisoned=(),
            total_examples=200, probe_q=8, local_warmup_steps=1,
            layers=4, t_rounds=1, batch_size=8, seed=0, seq_len=16,
            num_classes=4, use_channel=True)
REGISTERED = 24


@pytest.fixture(scope="module")
def fed():
    return Federation(FedConfig(**CHAN), backend="batched")


def _pop(fed, **kw):
    kw.setdefault("registered", REGISTERED)
    pop = PopulationRuntime(fed, PopulationConfig(**kw))
    fed._bind_population(pop)
    return pop


def _install(pop, assignment):
    """Arbitrary cohort schedule: put ``assignment[s]`` in slot ``s``."""
    pop.slot_to_id = np.asarray(assignment, np.int64)
    pop._id_to_slot = {int(c): s for s, c in enumerate(assignment)}


def _assert_rotation_is_identity_keyed(fed, pop, assignment):
    ref_u = np.asarray(fed._reference_basis())
    _install(pop, assignment)
    for slot, cid in enumerate(assignment):
        ch = fed.channel_for(slot, None)
        want_v = np.asarray(random_orthogonal(
            fed.fed.ssop_r, client_seed("elsa-salt", int(cid))))
        np.testing.assert_array_equal(np.asarray(ch.ssop.v), want_v)
        np.testing.assert_array_equal(np.asarray(ch.ssop.u), ref_u)


def test_rotation_invariant_under_slot_assignment_seeded_sweep(fed):
    pop = _pop(fed)
    rng = np.random.default_rng(7)
    for _ in range(25):
        assignment = rng.choice(REGISTERED, size=CHAN["n_clients"],
                                replace=False)
        _assert_rotation_is_identity_keyed(fed, pop, assignment)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(0, REGISTERED - 1),
                    min_size=CHAN["n_clients"],
                    max_size=CHAN["n_clients"], unique=True))
    def test_rotation_invariant_under_slot_assignment_hypothesis(
            fed, assignment):
        _assert_rotation_is_identity_keyed(fed, _pop(fed), assignment)


def test_identities_sharing_a_slot_get_distinct_rotations(fed):
    pop = _pop(fed)
    _install(pop, [3, 1, 2, 0])
    round0 = fed.channel_for(0, None)
    _install(pop, [19, 1, 2, 0])          # slot 0 swaps 3 -> 19
    round1 = fed.channel_for(0, None)
    assert (np.asarray(round0.ssop.v) != np.asarray(round1.ssop.v)).any()
    np.testing.assert_array_equal(np.asarray(round0.ssop.u),
                                  np.asarray(round1.ssop.u))


def test_returning_identity_rotation_bit_exact_after_eviction(fed):
    pop = _pop(fed, channel_cache=4)
    first = pop.channel_for_id(20)
    want = {f: np.asarray(getattr(first.ssop, f))
            for f in ("u", "v", "w", "w_inv")}
    for cid in (5, 6, 7, 8, 9):           # cap 4: 20 falls off the LRU
        pop.channel_for_id(cid)
    assert 20 not in pop._channels
    again = pop.channel_for_id(20)
    assert again is not first             # regenerated, not cached
    for f, ref in want.items():
        np.testing.assert_array_equal(np.asarray(getattr(again.ssop, f)),
                                      ref)


def test_channel_cache_telemetry_gauges(fed):
    with tm.session() as tel:
        pop = _pop(fed, channel_cache=4)
        for cid in (0, 1, 2, 3, 0, 9):    # 5 misses, 1 hit, 1 eviction
            pop.channel_for_id(cid)
        pop._round_ids = pop.slot_to_id
        pop.end_round(0)
    assert tel.gauge("population.channel_cache_size") == 4
    assert tel.gauge("population.channel_cache_hits") == 1
    assert tel.gauge("population.channel_cache_misses") == 5
    assert tel.gauge("population.channel_cache_evictions") == 1


# ---------------------------------------------------------------------------
# straggler trust attribution
# ---------------------------------------------------------------------------

def _swap_out(pop, straggler, start=1):
    """Advance the (deterministic) cohort schedule until the straggler
    is out of the cohort entirely; returns the new slot-0 occupant."""
    r = start
    while straggler in {int(c) for c in pop.slot_to_id}:
        pop.begin_round(r)
        r += 1
    return int(pop.slot_to_id[0])


def test_straggler_verdict_lands_on_pinned_identity_deadline_path():
    """The deadline write-back path: ``screen_cohort`` on a sender slot
    resolves the verdict to the pinned dispatch-time identity."""
    fed = Federation(FedConfig(**CHAN, screen=True), backend="batched")
    pop = _pop(fed, seed=2)
    pop.begin_round(0)
    straggler = pop.pin(0)                # dispatched from round 0's cohort
    newcomer = _swap_out(pop, straggler)  # cohort swapped mid-flight
    assert newcomer != straggler
    kept, _ = fed.screen_cohort([0], [fed.lora0], [1.0], fed.lora0)
    assert len(kept) == 1                 # zero-delta update passes
    reg = pop.registry
    assert reg.screen_passes[straggler] == 1
    assert reg.screen_passes[newcomer] == 0
    assert reg.screen_fails[newcomer] == 0


def test_straggler_verdict_lands_on_pinned_identity_async_path():
    """The async per-arrival path: ``record_trust(pinned_id, ok)`` hits
    the straggler's registry row, not the slot ledger of the new
    occupant."""
    fed = Federation(FedConfig(**CHAN, screen=True), backend="batched")
    pop = _pop(fed, seed=2)
    pop.begin_round(0)
    straggler = pop.pin(0)
    newcomer = _swap_out(pop, straggler)
    assert newcomer != straggler
    pop.record_trust(pop.pinned(0), False)   # nonfinite arrival, say
    reg = pop.registry
    beta = fed.trust_ledger.beta
    assert reg.screen_fails[straggler] == 1
    np.testing.assert_allclose(reg.trust[straggler], beta * 1.0)
    # the new occupant is untouched, in registry and slot ledger alike
    assert reg.trust[newcomer] == 1.0
    assert reg.screen_fails[newcomer] == 0
    assert fed.trust_ledger.scores[0] == 1.0


def test_in_cohort_verdict_mirrors_ledger_and_registry():
    fed = Federation(FedConfig(**CHAN, screen=True), backend="batched")
    pop = _pop(fed, seed=2)
    pop.begin_round(0)
    cid = int(pop.slot_to_id[2])
    pop.record_trust(cid, False)
    assert pop.registry.trust[cid] == fed.trust_ledger.scores[2]
    assert pop.registry.trust[cid] < 1.0
    assert pop.registry.screen_fails[cid] == 1


@pytest.mark.parametrize("policy", ["deadline", "async"])
def test_scheduler_verdicts_attributed_to_dispatched_ids(policy):
    """End-to-end: every identity carrying a screening verdict after a
    deadline/async run was actually dispatched (pinned) at some point —
    the slot-reuse bug attributed verdicts to whoever happened to hold
    the slot at write-back."""
    fed = Federation(FedConfig(**CHAN, screen=True), backend="batched")
    pop = _pop(fed, registered=16, seed=1)
    pins = []
    orig_pin = pop.pin
    pop.pin = lambda slot: (pins.append(orig_pin(slot)), pins[-1])[1]
    h = fed.run("fedavg", global_rounds=2, steps_per_round=2,
                runtime=RuntimeConfig(policy=policy), population=pop)
    assert np.isfinite(h["loss"]).all()
    reg = pop.registry
    judged = reg.screen_passes + reg.screen_fails
    assert judged.sum() > 0
    assert set(np.flatnonzero(judged)) <= set(pins)


def test_async_emits_screening_verdict_counters():
    """PR 7 caveat closed: the async per-arrival screening path now
    counts its verdicts, so telemetry reports are no longer blind."""
    fed = Federation(FedConfig(**CHAN, screen=True), backend="batched")
    with tm.session() as tel:
        fed.run("fedavg", global_rounds=2, steps_per_round=2,
                runtime=RuntimeConfig(policy="async"))
    counts = tel.counters_by_name("screening.verdicts")
    assert sum(counts.values()) > 0
