"""Environment fingerprint for golden-history records.

Golden histories pin *bit-level* reproducibility, but CPU f32 bits are
only stable within one numerical environment: a jax/jaxlib upgrade
changes XLA codegen (fusion, FMA contraction), the split-model gradient
map is chaotic (parameter-Lipschitz ~1e5, docs/engine.md), and the
recorded trajectories drift by ~1e-3 on a two-round horizon.  Each
golden therefore carries the fingerprint of the environment it was
recorded in: a matching environment asserts at float precision
(atol 1e-9 ≈ bit-identical for f32), a drifted one falls back to a
tolerance band that still catches wiring bugs (wrong method, broken
aggregation, channel misrouting) without failing on codegen drift.

Re-pin after an intentional container upgrade with::

    PYTHONPATH=src python tests/golden/regen_bert_parity.py
"""
import platform
import sys


def fingerprint() -> dict:
    import jax
    import jaxlib
    import numpy
    return {
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "numpy": numpy.__version__,
        "python": "%d.%d" % sys.version_info[:2],
        "machine": platform.machine(),
        "backend": jax.default_backend(),
    }


def matches(recorded) -> bool:
    """True when the current environment is the one the golden was
    recorded in (goldens predating the fingerprint never match)."""
    return recorded == fingerprint() if recorded else False
