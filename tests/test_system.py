"""End-to-end behaviour tests: the full ELSA federation pipeline on a
reduced BERT (Alg. 1, all three phases) and the split-vs-centralized
equivalence that underpins it."""
import numpy as np
import pytest

from repro.federation.simulation import FedConfig, Federation


@pytest.fixture(scope="module")
def federation():
    return Federation(FedConfig(
        n_clients=6, n_edges=2, alpha=0.2, poisoned=(4,),
        total_examples=900, probe_q=12, local_warmup_steps=3,
        lr=2e-2, layers=4, t_rounds=1, batch_size=16))


def test_elsa_full_pipeline_runs_and_learns(federation):
    h = federation.run("elsa", global_rounds=4, steps_per_round=4)
    assert len(h["accuracy"]) >= 1
    assert np.isfinite(h["loss"]).all()
    # training loss decreases across rounds
    assert h["loss"][-1] < h["loss"][0] + 0.05
    assert 0.0 <= h["final_accuracy"] <= 1.0


def test_clustering_phase_produces_valid_partition(federation):
    div, trust, cres, _ = federation.profile_clients()
    n = federation.fed.n_clients
    assert div.shape == (n, n) and (div >= -1e-6).all()
    assert trust.shape == (n,)
    placed = [c for g in cres.groups.values() for c in g]
    assert len(placed) == len(set(placed))      # no client in two groups
    for c in placed:
        assert cres.assignment[c] is not None


def test_baselines_run(federation):
    for method in ("fedavg", "fedavg-random", "fedprox", "fedams",
                   "elsa-nocluster"):
        h = federation.run(method, global_rounds=2, steps_per_round=2)
        assert np.isfinite(h["final_accuracy"])


def test_convergence_criterion_stops_early():
    fed = Federation(FedConfig(
        n_clients=4, n_edges=2, alpha=0.5, poisoned=(),
        total_examples=400, probe_q=8, local_warmup_steps=2,
        lr=1e-6, xi=1e3, layers=4))   # huge xi -> stop after round 0
    h = fed.run("fedavg", global_rounds=6, steps_per_round=2)
    assert len(h["round"]) <= 2
