import os
import sys

# tests run single-device (the dry-run sets its own 512-device flag in a
# separate process); keep jax quiet and on CPU
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
