import os
import sys

# tests run single-device (the dry-run sets its own 512-device flag in a
# separate process); keep jax quiet and on CPU
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def make_abstract_mesh(sizes, names):
    """AbstractMesh across jax versions: (sizes, names) vs shape_tuple."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))
