"""Shared benchmark helpers: timing + CSV/JSON emission + telemetry."""
import contextlib
import json
import time

from repro import telemetry as tm


def timeit(fn, *args, repeats=3, warmup=1, **kw):
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # us


def fed_round_config(clients: int, model: str, total_examples: int) -> dict:
    """The fed-round benchmark FedConfig kwargs (ISSUE acceptance shape:
    reduced 4-layer model, one edge round, no profiling-phase method),
    shared by bench_fed_round and bench_sharded_round so the two
    records always measure the same workload per client."""
    return dict(n_clients=clients, n_edges=4, alpha=0.1,
                poisoned=(3, 8, 12, 17), total_examples=total_examples,
                probe_q=16, local_warmup_steps=2, layers=4, lr=5e-3,
                t_rounds=1, batch_size=16, model=model)


def time_fed_round(make_federation, steps: int) -> float:
    """One warmup ``fedavg`` global round (compiles round functions,
    builds per-client channels), then the timed round."""
    fed = make_federation()
    fed.run("fedavg", global_rounds=1, steps_per_round=steps)
    t0 = time.perf_counter()
    fed.run("fedavg", global_rounds=1, steps_per_round=steps)
    return time.perf_counter() - t0


def emit(name: str, us_per_call: float, derived):
    print(f"{name},{us_per_call:.1f},{derived}")


def write_json(path: str, payload: dict):
    """Persist a benchmark record (BENCH_*.json) for CI / regression diff."""
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def telemetry_path(json_path: str) -> str:
    """Telemetry sidecar for a BENCH json: ``X.json`` -> ``X.telemetry.
    jsonl`` (written next to the record so CI artifact uploads of the
    bench directory carry both)."""
    base = json_path[:-5] if json_path.endswith(".json") else json_path
    return base + ".telemetry.jsonl"


@contextlib.contextmanager
def bench_telemetry(bench: str, json_path: str = None, **meta):
    """Run a bench's measured section under a telemetry session sharing
    one schema across all ``bench_*`` scripts: meta carries the bench
    name + config labels, and the JSONL lands beside the BENCH json
    (``json_path=None`` collects without exporting)."""
    jsonl = telemetry_path(json_path) if json_path else None
    with tm.session(meta={"bench": bench, **meta}, jsonl=jsonl) as tel:
        yield tel
