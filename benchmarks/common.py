"""Shared benchmark helpers: timing + CSV/JSON emission."""
import json
import time


def timeit(fn, *args, repeats=3, warmup=1, **kw):
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # us


def emit(name: str, us_per_call: float, derived):
    print(f"{name},{us_per_call:.1f},{derived}")


def write_json(path: str, payload: dict):
    """Persist a benchmark record (BENCH_*.json) for CI / regression diff."""
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
