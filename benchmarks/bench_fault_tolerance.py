"""Fault-tolerance study: screened vs unscreened accuracy under faults.

Three arms of the tuned encoder federation (docs/robustness.md), all on
the sync runtime so every arm sees the same dispatch schedule:

- **clean**: no faults, no screening — the reference accuracy;
- **unscreened**: a seeded ``FaultTrace`` corrupts every update from a
  fixed faulty subset of clients, aggregation is untouched;
- **screened**: the same trace (bit-identical fault schedule), with the
  server-side screening stage + trust EMA enabled.

Per faulty-fraction arm the study records the **screened gap** (clean
minus screened — how much accuracy screening fails to rescue) and the
**screened advantage** (screened minus unscreened — how much screening
buys over doing nothing).  The headline metrics feed
``benchmarks/check_regression.py``: the worst-case advantage is a CI
floor and the worst-case gap a ceiling, so the robustness claim cannot
silently rot.

Corruption modes are chosen so each arm's screen has a sound majority
to screen *against*: at 25% faulty the cohort median/mean-direction
screens are honest-dominated, so NaN + sign-flip both apply; at 50%
faulty only NaN injection is used (the finite screen needs no cohort
statistics, so it works at any contamination level — direction/norm
screens at half contamination would gate on a poisoned reference).

Full mode (committed ``BENCH_fault_tolerance.json``) runs the gate
horizon; ``--quick`` shortens it and drops to the single 25% arm for
the CI smoke/gate.
"""
import os

from benchmarks.common import bench_telemetry, emit, write_json
from repro.federation.simulation import FedConfig, Federation
from repro.federation.topology import make_fault_trace
from repro.runtime import RuntimeConfig

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_fault_tolerance.json")

# the tier-1 convergence gate's tuned bert-base stack (tests/
# test_convergence.py), widened to 8 clients so the faulty subsets
# below stay a cohort minority where the screens assume one
BASE = dict(n_clients=8, n_edges=2, alpha=5.0, poisoned=(),
            total_examples=800, probe_q=8, local_warmup_steps=2,
            layers=4, t_rounds=1, batch_size=16, seed=0, seq_len=32,
            class_sharpness=10.0, background_frac=0.0, num_classes=4,
            use_channel=False, clip_norm=1.0, lr=5e-3, head_lr=0.4,
            pooling="mean", server_opt="fedadam", server_lr=0.03)

ROUNDS, STEPS = 14, 6

#: (label, faulty_frac, corruption modes) — see the module docstring
#: for why the mode set narrows as the contamination level rises.
ARMS = (
    ("frac25", 0.25, ("nan", "signflip")),
    ("frac50", 0.50, ("nan",)),
)


def _final_acc(screen: bool, faults, rounds: int) -> float:
    fed = Federation(FedConfig(**BASE, screen=screen), backend="batched")
    h = fed.run("elsa", global_rounds=rounds, steps_per_round=STEPS,
                runtime=RuntimeConfig(policy="sync", faults=faults))
    return float(h["final_accuracy"])


def run(quick: bool = False, write: bool = True, out: str = None):
    rounds = 8 if quick else ROUNDS
    arms = ARMS[:1] if quick else ARMS
    out_path = os.path.abspath(out or OUT_PATH)
    with bench_telemetry("fault_tolerance", out_path if write else None,
                         rounds=rounds, quick=quick):
        clean = _final_acc(False, None, rounds)
        emit("fault_tolerance_clean", 0.0, f"final={clean:.4f}")

        results, gaps, advantages = {}, [], []
        for label, frac, modes in arms:
            faults = make_fault_trace(BASE["n_clients"], faulty_frac=frac,
                                      corrupt_rate=1.0,
                                      corrupt_modes=modes, seed=11)
            screened = _final_acc(True, faults, rounds)
            unscreened = _final_acc(False, faults, rounds)
            gap = clean - screened
            adv = screened - unscreened
            results[label] = {
                "faulty_frac": frac, "corrupt_modes": list(modes),
                "n_faulty": len(faults.faulty),
                "screened_accuracy": round(screened, 4),
                "unscreened_accuracy": round(unscreened, 4),
                "screened_gap": round(gap, 4),
                "screened_advantage": round(adv, 4),
            }
            gaps.append(gap)
            advantages.append(adv)
            emit(f"fault_tolerance_{label}", 0.0,
                 f"screened={screened:.4f} unscreened={unscreened:.4f} "
                 f"gap={gap:.4f} adv={adv:.4f}")

    payload = {
        "config": {**{k: (list(v) if isinstance(v, tuple) else v)
                      for k, v in BASE.items()},
                   "rounds": rounds, "steps": STEPS, "quick": quick},
        "clean_accuracy": round(clean, 4),
        "arms": results,
        # regression-gate metrics: the worst arm on each axis
        "min_screened_advantage": round(min(advantages), 4),
        "max_screened_gap": round(max(gaps), 4),
    }
    if write:
        write_json(out_path, payload)
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shortened horizon + single arm for the CI gate "
                         "(no BENCH json unless --out is given)")
    ap.add_argument("--out", default=None,
                    help="write the bench JSON here (CI regression gate)")
    args = ap.parse_args()
    print(run(quick=args.quick, write=args.out is not None or not args.quick,
              out=args.out))
