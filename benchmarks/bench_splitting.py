"""Table V reproduction: static vs dynamic splitting under a heterogeneous
network (40% resource-constrained clients), via the compute/communication
cost model.

Metrics follow the paper's footnote definitions: Comp. Util. (fraction of
client FLOPS engaged), Comm. Util. (fraction of bandwidth used), Overall
Eff. (geometric composite), Task Failure Rate (iteration latency > system
timeout).
"""
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.splitting import SplitPolicy, splits_for_population
from repro.federation.topology import make_topology

M_BLOCKS = 12
FLOPS_PER_BLOCK = 2 * 110e6 / 12 * 32 * 128 * 2   # BERT-base-ish per batch
ACT_BYTES = 32 * 128 * 768 * 4                     # batch x seq x D fp32
RHO = 2.1
EDGE_FLOPS = 5e12


def simulate(splits, topo, timeout_factor=2.5):
    n = len(topo.capacity)
    t_comp = np.array([(p + o) * FLOPS_PER_BLOCK / topo.capacity[i]
                       for i, (p, q, o) in enumerate(splits)])
    t_edge = np.array([q * FLOPS_PER_BLOCK / EDGE_FLOPS
                       for (p, q, o) in splits])
    t_comm = np.array([2 * ACT_BYTES / RHO / topo.bandwidth[i]
                       for i in range(n)])
    total = t_comp + t_edge + t_comm
    timeout = timeout_factor * np.median(total)
    fail = total > timeout
    comp_util = np.mean(np.clip(t_comp / total, 0, 1))
    comm_util = np.mean(np.clip(t_comm / total, 0, 1))
    # engaged-resource balance: product of how evenly compute and comm are
    # used, discounted by failures (composite like the paper's Overall Eff.)
    overall = (np.sqrt(comp_util * comm_util) * 2 /
               (np.sqrt(comp_util * comm_util) + 0.5)) * (1 - fail.mean())
    return dict(comp=100 * comp_util, comm=100 * comm_util,
                overall=100 * min(overall, 1.0), fail=100 * fail.mean())


def run(n_clients=40, seed=0):
    topo = make_topology(n_clients, 4, constrained_frac=0.4, seed=seed)
    policy = SplitPolicy(num_blocks=M_BLOCKS, o_fix=2, p_min=1, p_max=6)

    def compute():
        rows = {}
        for p_static in (1, 3, 6, 9):
            splits = [(p_static, M_BLOCKS - p_static - 2, 2)] * n_clients
            rows[f"static_p{p_static}"] = simulate(splits, topo)
        dyn = splits_for_population(topo.capacity, topo.bandwidth, policy)
        rows["dynamic"] = simulate(dyn, topo)
        return rows

    rows, us = timeit(compute, repeats=3)
    for name, r in rows.items():
        emit(f"table5_{name}", us / 5,
             f"comp={r['comp']:.1f}% comm={r['comm']:.1f}% "
             f"overall={r['overall']:.1f}% fail={r['fail']:.1f}%")
    return rows


if __name__ == "__main__":
    run()
