"""Table III reproduction: total communication time to target across the
eight task profiles, ELSA (rho=3.3 sketch, the paper's recommended band) vs the uncompressed Vanilla
model, via the Eq. 22-24 communication model.

The paper reports 69.3%-73.7% reduction vs Vanilla; we reproduce the model
with the paper's BERT-base numbers (D=768, fp32, B_n in [50,100] Mbps).
"""
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.comm_model import CommConfig, total_comm_time

# (task, seq_len mu, rounds-to-target G for vanilla)
TASKS = [("ag_news", 64, 60), ("banking", 48, 42), ("emotion", 48, 52),
         ("trec", 32, 35), ("rte", 128, 38), ("cb", 128, 47),
         ("multirc", 256, 52), ("squad", 192, 65)]


def run(n_clients=20, seed=0):
    rng = np.random.default_rng(seed)
    bw = rng.uniform(50, 100, n_clients) * 1e6 / 8.0
    batches = rng.integers(8, 33, n_clients).astype(float)
    rows = {}

    def compute():
        out = {}
        for task, mu, g_vanilla in TASKS:
            base = dict(t_rounds=2, bytes_per_param=4.0, seq_len=mu,
                        d_hidden=768, lora_bytes=4 * 2 * 768 * 8 * 12)
            van = CommConfig(rho=1.0, **base)
            # compression converges in slightly more rounds (fidelity loss)
            elsa = CommConfig(rho=3.3, **base)
            g_elsa = int(np.ceil(g_vanilla * 1.08))
            t_v = total_comm_time(van, batches, bw, g_vanilla)
            t_e = total_comm_time(elsa, batches, bw, g_elsa)
            out[task] = (t_v, t_e, 1.0 - t_e / t_v)
        return out

    rows, us = timeit(compute, repeats=5)
    for task, (tv, te, red) in rows.items():
        emit(f"table3_commtime_{task}", us / len(TASKS),
             f"vanilla_s={tv:.1f} elsa_s={te:.1f} reduction={red:.3f}")
    reds = [r for _, _, r in rows.values()]
    emit("table3_summary", us,
         f"mean_reduction={np.mean(reds):.3f} (paper: 0.693-0.737 range "
         f"vs vanilla at rho=3.26-3.78 effective)")
    return rows


if __name__ == "__main__":
    run()
