"""Table III reproduction: total communication time to target across the
eight task profiles, ELSA (rho~3.3 sketch, the paper's recommended band) vs
the uncompressed Vanilla model, via the Eq. 22-24 communication model.

The paper reports 69.3%-73.7% reduction vs Vanilla; we reproduce the model
with the paper's BERT-base setup (D=768, fp32, B_n in [50,100] Mbps) — but
every CommConfig field is now *derived* from the real artifacts via
``comm_config_from``: D and zeta from the bert-base ArchConfig, rho from an
actual count-sketch ``SketchPlan`` (so it is the effective D/(Y*Z), not a
typed-in target), and lora_bytes from the model's LoRA parameter specs.
"""
import dataclasses

import numpy as np

from benchmarks.common import emit, timeit
from repro.configs import get_config
from repro.core.comm_model import comm_config_from, total_comm_time
from repro.core.sketch import make_plan

# (task, seq_len mu, rounds-to-target G for vanilla)
TASKS = [("ag_news", 64, 60), ("banking", 48, 42), ("emotion", 48, 52),
         ("trec", 32, 35), ("rte", 128, 38), ("cb", 128, 47),
         ("multirc", 256, 52), ("squad", 192, 65)]


@dataclasses.dataclass
class _Fed:
    """Minimal FedConfig stand-in for comm_config_from (paper setup)."""
    t_rounds: int = 2
    seq_len: int = 128
    num_classes: int = 4


def run(n_clients=20, seed=0):
    rng = np.random.default_rng(seed)
    bw = rng.uniform(50, 100, n_clients) * 1e6 / 8.0
    batches = rng.integers(8, 33, n_clients).astype(float)

    # the paper's model at fp32; a real plan in the recommended rho band
    # (Y=3 rows, Z=78 buckets -> effective rho = 768/234 = 3.28)
    cfg = get_config("bert-base").with_(param_dtype="float32",
                                        activation_dtype="float32")
    plan = make_plan(cfg.d_model, 3, 78, seed=seed)
    fed = _Fed()

    def compute():
        out = {}
        for task, mu, g_vanilla in TASKS:
            van = comm_config_from(cfg, fed, plan=None, seq_len=mu)
            elsa = comm_config_from(cfg, fed, plan=plan, seq_len=mu)
            # compression converges in slightly more rounds (fidelity loss)
            g_elsa = int(np.ceil(g_vanilla * 1.08))
            t_v = total_comm_time(van, batches, bw, g_vanilla)
            t_e = total_comm_time(elsa, batches, bw, g_elsa)
            out[task] = (t_v, t_e, 1.0 - t_e / t_v)
        return out

    rows, us = timeit(compute, repeats=5)
    for task, (tv, te, red) in rows.items():
        emit(f"table3_commtime_{task}", us / len(TASKS),
             f"vanilla_s={tv:.1f} elsa_s={te:.1f} reduction={red:.3f}")
    reds = [r for _, _, r in rows.values()]
    emit("table3_summary", us,
         f"mean_reduction={np.mean(reds):.3f} rho_effective={plan.rho:.2f} "
         f"(paper: 0.693-0.737 range vs vanilla)")
    return rows


if __name__ == "__main__":
    run()
