"""Table II / Fig. 4 reproduction (relative orderings on synthetic
non-IID data): ELSA vs FedAvg vs FedAvg(Random), two heterogeneity levels.

Absolute accuracies are not comparable to the paper (offline synthetic
corpus — DESIGN.md §8); the asserted properties are the paper's relative
claims: ELSA >= FedAvg >= FedAvg(Random) at convergence.
"""
import time

from benchmarks.common import emit
from repro.federation.simulation import FedConfig, Federation


def run(alphas=(0.1, 0.2), rounds=5, steps=5):
    out = {}
    for alpha in alphas:
        fed = Federation(FedConfig(
            n_clients=8, n_edges=2, alpha=alpha, poisoned=(2, 7),
            total_examples=2000, probe_q=16, local_warmup_steps=5,
            lr=3e-2, layers=4, t_rounds=1))
        t0 = time.perf_counter()
        res = {}
        for method in ("elsa", "fedavg", "fedavg-random"):
            h = fed.run(method, global_rounds=rounds,
                        steps_per_round=steps)
            res[method] = h["final_accuracy"]
        us = (time.perf_counter() - t0) * 1e6
        emit(f"table2_accuracy_alpha{alpha}", us,
             " ".join(f"{m}={a:.4f}" for m, a in res.items()))
        out[alpha] = res
    return out


if __name__ == "__main__":
    run()
