"""Convergence study: aggregation mode x server optimizer x clipping.

The study behind the tier-1 convergence gate (docs/convergence.md):
runs the tuned small-federation config on BOTH registered model
families and sweeps the three convergence-stack axes —

- ``aggregate``: product-space (weight-delta mean, anchored pinv
  re-fit) vs legacy factor averaging;
- ``server_opt``: none vs bias-corrected FedAdam (small server lr);
- ``clip_norm``: per-client global-norm clipping on vs off

— recording final/best synthetic-task test accuracy per combination
plus the task's chance level.  The headline numbers feed
``benchmarks/check_regression.py``: the tuned stack's accuracy margin
over chance is a CI floor, so the repo's accuracy claims cannot
silently regress back to chance.

Full mode (committed ``BENCH_convergence.json``) runs the gate-length
schedules; ``--quick`` shortens the horizon for the CI smoke/gate but
keeps every axis.
"""
import os

from benchmarks.common import bench_telemetry, emit, write_json
from repro.federation.simulation import FedConfig, Federation

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_convergence.json")

# same base as tests/test_convergence.py (the gate configs), minus the
# swept axes
BASE = dict(n_clients=4, n_edges=2, alpha=5.0, poisoned=(),
            total_examples=800, probe_q=8, local_warmup_steps=2,
            layers=4, t_rounds=1, batch_size=16, seed=0, seq_len=32,
            class_sharpness=10.0, background_frac=0.0, num_classes=4,
            use_channel=False)

FAMILIES = {
    # family -> (fed overrides, rounds, steps, chance accuracy)
    "bert-base": (dict(lr=5e-3, head_lr=0.4, pooling="mean"),
                  20, 6, 0.25),
    "llama3-8b": (dict(model="llama3-8b", vocab_size=32, lr=0.5),
                  10, 12, 1.0 / 32),
}

#: (label, fed overrides) — the swept stack variants.  "tuned" is the
#: product+clip core; "factor-agg"/"no-clip" each flip one of its axes
#: off, and "fedadam" adds the server step on top (for bert-base that
#: IS the tier-1 gate stack; the causal-LM gate runs the core without
#: a server opt).  The gate metric below takes the better of
#: tuned/fedadam per family, i.e. the best gate-candidate stack.
VARIANTS = (
    ("tuned", dict(aggregate="product", clip_norm=1.0)),
    ("factor-agg", dict(aggregate="factor", clip_norm=1.0)),
    ("no-clip", dict(aggregate="product", clip_norm=0.0)),
    ("fedadam", dict(aggregate="product", clip_norm=1.0,
                     server_opt="fedadam", server_lr=0.03)),
)


def _accuracy(kw: dict, rounds: int, steps: int):
    fed = Federation(FedConfig(**kw))
    h = fed.run("elsa", global_rounds=rounds, steps_per_round=steps)
    return float(h["final_accuracy"]), float(max(h["accuracy"]))


def run(quick: bool = False, write: bool = True, out: str = None):
    results, margins = {}, []
    out_path = os.path.abspath(out or OUT_PATH)
    with bench_telemetry("convergence", out_path if write else None,
                         quick=quick):
        for family, (overrides, rounds, steps, chance) in FAMILIES.items():
            if quick:
                rounds = max(rounds // 2 - 2, 4) if family == "bert-base" \
                    else 6
            fam = {"chance": chance, "rounds": rounds, "steps": steps,
                   "variants": {}}
            for label, stack in VARIANTS:
                final, best = _accuracy({**BASE, **overrides, **stack},
                                        rounds, steps)
                fam["variants"][label] = {
                    "final_accuracy": round(final, 4),
                    "best_accuracy": round(best, 4)}
                emit(f"convergence_{family}_{label}", 0.0,
                     f"final={final:.4f} best={best:.4f} "
                     f"chance={chance:.4f}")
            tuned = max(fam["variants"]["tuned"]["final_accuracy"],
                        fam["variants"]["fedadam"]["final_accuracy"])
            fam["tuned_margin_over_chance"] = round(tuned - chance, 4)
            margins.append(fam["tuned_margin_over_chance"])
            results[family] = fam
    payload = {
        "config": {**{k: (list(v) if isinstance(v, tuple) else v)
                      for k, v in BASE.items()}, "quick": quick},
        "families": results,
        # the regression-gate metric: worst tuned-stack margin over
        # chance across families
        "min_margin_over_chance": round(min(margins), 4),
        # the headline comparison: product-space vs factor averaging
        "product_beats_factor": {
            f: round(r["variants"]["tuned"]["final_accuracy"]
                     - r["variants"]["factor-agg"]["final_accuracy"], 4)
            for f, r in results.items()},
    }
    if write:
        write_json(out_path, payload)
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shortened horizons for the CI gate (no BENCH "
                         "json unless --out is given)")
    ap.add_argument("--out", default=None,
                    help="write the bench JSON here (CI regression gate)")
    args = ap.parse_args()
    print(run(quick=args.quick, write=args.out is not None or not args.quick,
              out=args.out))
