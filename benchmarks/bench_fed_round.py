"""One-global-round wall-clock: sequential reference vs batched engine.

Times ``Federation.run`` for a single global round on the ISSUE's
acceptance configuration — 20 clients, 4 local steps, a reduced 4-layer
model, CPU — with method ``fedavg`` (all clients in one group, dynamic
splits and the SS-OP∘sketch channel active, no profiling phase) so the
measurement isolates local split training + aggregation.  Each backend
gets one warmup run first (compiles round functions, builds per-client
channels), then the timed run; speedup = reference / batched.

``--model`` selects any architecture registered in
:mod:`repro.models.split_api` (default: the paper's ``bert-base``
encoder; e.g. ``llama3-8b`` exercises the causal-LM split path) — CI
runs the quick smoke on both registered families.

Writes ``BENCH_fed_round.json`` at the repo root via
``benchmarks.common.write_json`` and prints the usual CSV line.
"""
import os
import time

from benchmarks.common import emit, write_json
from repro.federation.simulation import FedConfig, Federation

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_fed_round.json")


def _config(clients=20, model="bert-base"):
    return dict(n_clients=clients, n_edges=4, alpha=0.1,
                poisoned=(3, 8, 12, 17), total_examples=2000, probe_q=16,
                local_warmup_steps=2, layers=4, lr=5e-3, t_rounds=1,
                batch_size=16, model=model)


def _time_round(backend: str, steps: int, clients: int,
                model: str) -> float:
    fed = Federation(FedConfig(**_config(clients, model)), backend=backend)
    fed.run("fedavg", global_rounds=1, steps_per_round=steps)   # warmup
    t0 = time.perf_counter()
    fed.run("fedavg", global_rounds=1, steps_per_round=steps)
    return time.perf_counter() - t0


def run(steps: int = 4, clients: int = 20, model: str = "bert-base",
        write: bool = True):
    t_batched = _time_round("batched", steps, clients, model)
    t_reference = _time_round("reference", steps, clients, model)
    speedup = t_reference / t_batched
    payload = {
        "config": {"clients": clients, "steps_per_round": steps,
                   "model": model, "layers": 4, "t_rounds": 1,
                   "batch_size": 16, "method": "fedavg", "device": "cpu"},
        "reference_s": round(t_reference, 3),
        "batched_s": round(t_batched, 3),
        "speedup": round(speedup, 2),
    }
    if write:
        write_json(os.path.abspath(OUT_PATH), payload)
    emit("fed_round_reference", t_reference * 1e6,
         f"{model}:{clients}x{steps}steps")
    emit("fed_round_batched", t_batched * 1e6, f"speedup={speedup:.2f}x")
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny CI smoke configuration (no BENCH json)")
    ap.add_argument("--model", default="bert-base",
                    help="registered split-model name (bert-base, "
                         "llama3-8b, ...)")
    args = ap.parse_args()
    if args.quick:
        print(run(steps=2, clients=6, model=args.model, write=False))
    else:
        print(run(model=args.model))
