"""One-global-round wall-clock: sequential reference vs batched engine.

Times ``Federation.run`` for a single global round on the ISSUE's
acceptance configuration — 20 clients, 4 local steps, a reduced 4-layer
model, CPU — with method ``fedavg`` (all clients in one group, dynamic
splits and the SS-OP∘sketch channel active, no profiling phase) so the
measurement isolates local split training + aggregation.  Each backend
gets one warmup run first (compiles round functions, builds per-client
channels), then the timed run; speedup = reference / batched.

``--model`` selects any architecture registered in
:mod:`repro.models.split_api` (default: the paper's ``bert-base``
encoder; e.g. ``llama3-8b`` exercises the causal-LM split path) — CI
runs the quick smoke on both registered families.

Writes ``BENCH_fed_round.json`` at the repo root via
``benchmarks.common.write_json`` and prints the usual CSV line.
"""
import os

from benchmarks.common import (emit, fed_round_config, time_fed_round,
                               write_json)
from repro.federation.simulation import FedConfig, Federation

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_fed_round.json")


def _time_round(backend: str, steps: int, cfg_kw: dict) -> float:
    return time_fed_round(
        lambda: Federation(FedConfig(**cfg_kw), backend=backend), steps)


def run(steps: int = 4, clients: int = 20, model: str = "bert-base",
        write: bool = True, out: str = None):
    cfg_kw = fed_round_config(clients, model, total_examples=2000)
    t_batched = _time_round("batched", steps, cfg_kw)
    t_reference = _time_round("reference", steps, cfg_kw)
    speedup = t_reference / t_batched
    payload = {
        # labels come from the shared config so the record can't drift
        # from the measured workload
        "config": {"clients": clients, "steps_per_round": steps,
                   "model": model, "layers": cfg_kw["layers"],
                   "t_rounds": cfg_kw["t_rounds"],
                   "batch_size": cfg_kw["batch_size"],
                   "method": "fedavg", "device": "cpu"},
        "reference_s": round(t_reference, 3),
        "batched_s": round(t_batched, 3),
        "speedup": round(speedup, 2),
    }
    if write:
        write_json(os.path.abspath(out or OUT_PATH), payload)
    emit("fed_round_reference", t_reference * 1e6,
         f"{model}:{clients}x{steps}steps")
    emit("fed_round_batched", t_batched * 1e6, f"speedup={speedup:.2f}x")
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny CI smoke configuration (no BENCH json "
                         "unless --out is given)")
    ap.add_argument("--model", default="bert-base",
                    help="registered split-model name (bert-base, "
                         "llama3-8b, ...)")
    ap.add_argument("--out", default=None,
                    help="write the bench JSON here (for the CI "
                         "regression gate / artifacts)")
    args = ap.parse_args()
    if args.quick:
        print(run(steps=2, clients=6, model=args.model,
                  write=args.out is not None, out=args.out))
    else:
        print(run(model=args.model, out=args.out))
