"""One-global-round wall-clock: sequential reference vs batched engine.

Times ``Federation.run`` for a single global round on the ISSUE's
acceptance configuration — 20 clients, 4 local steps, a reduced 4-layer
model, CPU — with method ``fedavg`` (all clients in one group, dynamic
splits and the SS-OP∘sketch channel active, no profiling phase) so the
measurement isolates local split training + aggregation.  Each backend
gets one warmup run first (compiles round functions, builds per-client
channels), then the timed run; speedup = reference / batched.

``--model`` selects any architecture registered in
:mod:`repro.models.split_api` (default: the paper's ``bert-base``
encoder; e.g. ``llama3-8b`` exercises the causal-LM split path) — CI
runs the quick smoke on both registered families.

Writes ``BENCH_fed_round.json`` at the repo root via
``benchmarks.common.write_json`` and prints the usual CSV line.
"""
import os
import time

from benchmarks.common import (bench_telemetry, emit, fed_round_config,
                               time_fed_round, write_json)
from repro.federation.simulation import FedConfig, Federation

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_fed_round.json")


def _time_round(backend: str, steps: int, cfg_kw: dict) -> float:
    return time_fed_round(
        lambda: Federation(FedConfig(**cfg_kw), backend=backend), steps)


def _time_round_telemetry(steps: int, cfg_kw: dict, json_path: str,
                          clients: int, model: str) -> float:
    """The batched round again, with telemetry collecting: the
    disabled/enabled wall-time ratio is the overhead gate's metric, and
    the collected JSONL ships beside the BENCH json."""
    fed = Federation(FedConfig(**cfg_kw), backend="batched")
    fed.run("fedavg", global_rounds=1, steps_per_round=steps)   # warmup
    with bench_telemetry("fed_round", json_path, backend="batched",
                         clients=clients, model=model, steps=steps):
        t0 = time.perf_counter()
        fed.run("fedavg", global_rounds=1, steps_per_round=steps)
        return time.perf_counter() - t0


def run(steps: int = 4, clients: int = 20, model: str = "bert-base",
        write: bool = True, out: str = None, quick: bool = False):
    if quick:
        # CI smoke config; never clobber the committed full-run record
        steps, clients = 2, 6
        write = write and out is not None
    cfg_kw = fed_round_config(clients, model, total_examples=2000)
    t_batched = _time_round("batched", steps, cfg_kw)
    t_reference = _time_round("reference", steps, cfg_kw)
    speedup = t_reference / t_batched
    out_path = os.path.abspath(out or OUT_PATH)
    t_telemetry = _time_round_telemetry(
        steps, cfg_kw, out_path if write else None, clients, model)
    telemetry_ratio = t_batched / t_telemetry
    payload = {
        # labels come from the shared config so the record can't drift
        # from the measured workload
        "config": {"clients": clients, "steps_per_round": steps,
                   "model": model, "layers": cfg_kw["layers"],
                   "t_rounds": cfg_kw["t_rounds"],
                   "batch_size": cfg_kw["batch_size"],
                   "method": "fedavg", "device": "cpu"},
        "reference_s": round(t_reference, 3),
        "batched_s": round(t_batched, 3),
        "speedup": round(speedup, 2),
        "telemetry_s": round(t_telemetry, 3),
        # disabled/enabled round time: < 1 means telemetry costs time;
        # the regression gate floors this at 0.95
        "telemetry_ratio": round(telemetry_ratio, 3),
    }
    if write:
        write_json(out_path, payload)
    emit("fed_round_reference", t_reference * 1e6,
         f"{model}:{clients}x{steps}steps")
    emit("fed_round_batched", t_batched * 1e6, f"speedup={speedup:.2f}x")
    emit("fed_round_telemetry", t_telemetry * 1e6,
         f"overhead_ratio={telemetry_ratio:.3f}")
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny CI smoke configuration (no BENCH json "
                         "unless --out is given)")
    ap.add_argument("--model", default="bert-base",
                    help="registered split-model name (bert-base, "
                         "llama3-8b, ...)")
    ap.add_argument("--out", default=None,
                    help="write the bench JSON here (for the CI "
                         "regression gate / artifacts)")
    args = ap.parse_args()
    print(run(model=args.model, out=args.out, quick=args.quick))
