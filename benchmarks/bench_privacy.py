"""Table VI reproduction: privacy/utility of the transmitted activations
under reconstruction and token-identification attacks.

Threat model (paper §IV.C): a semi-honest server observing the uplink.
- Direct: raw hidden states.
- Gaussian: + N(0, 0.25) noise (DP-style baseline).
- Sketch only: count-sketch compress (server knows the hashes, decodes).
- ELSA: SS-OP (secret V_n) + sketch; server decodes the sketch but cannot
  invert the semantic-subspace rotation.

Metrics: cosine similarity + MSE between true and reconstructed hiddens;
token identification accuracy via nearest-neighbor match against the
(public) embedding table.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.sketch import make_plan, compress, decompress
from repro.core.ssop import make_ssop, apply_ssop
from repro.models import bert as bert_mod
from repro.models.params import init_tree

RHOS = (2.1, 4.2, 8.4)


def _metrics(h_true, h_rec):
    ht = np.asarray(h_true, np.float64).reshape(-1, h_true.shape[-1])
    hr = np.asarray(h_rec, np.float64).reshape(-1, h_rec.shape[-1])
    num = (ht * hr).sum(-1)
    den = np.linalg.norm(ht, axis=-1) * np.linalg.norm(hr, axis=-1) + 1e-12
    cos = float((num / den).mean())
    mse = float(((ht - hr) ** 2).mean())
    return cos, mse


def _token_acc(h_rec, tokens, embed_table):
    """NN attack: match each reconstructed position to the vocab table."""
    hr = np.asarray(h_rec).reshape(-1, h_rec.shape[-1])
    et = np.asarray(embed_table)
    et_n = et / (np.linalg.norm(et, axis=-1, keepdims=True) + 1e-9)
    hr_n = hr / (np.linalg.norm(hr, axis=-1, keepdims=True) + 1e-9)
    pred = (hr_n @ et_n.T).argmax(-1)
    return float((pred == np.asarray(tokens).reshape(-1)).mean())


def run(seed=0):
    cfg = get_config("bert-base").reduced().with_(num_layers=4)
    tree = init_tree(bert_mod.bert_specs(cfg, 4), jax.random.PRNGKey(seed),
                     jnp.float32)
    frozen = tree["frozen"]
    toks = jax.random.randint(jax.random.PRNGKey(1), (16, 24), 0,
                              cfg.vocab_size)
    # transmitted hidden state: embedding + 1 block (p=1 cut, worst case)
    x = bert_mod.embed(cfg, frozen, toks)
    h = bert_mod.run_blocks(cfg, frozen, tree["lora"], x, 0, 1)
    emb_out = np.asarray(x)   # attack target resolvable at the embedding
    d = cfg.d_model
    table = frozen["embed"][:cfg.vocab_size]

    rows = []
    # Direct
    cos, mse = _metrics(h, h)
    rows.append(("direct", "-", cos, mse, _token_acc(x, toks, table)))
    # Gaussian noise
    noise = 0.5 * jax.random.normal(jax.random.PRNGKey(2), h.shape)
    cos, mse = _metrics(h, h + noise)
    rows.append(("gaussian", "-", cos, mse,
                 _token_acc(x + noise, toks, table)))
    for rho in RHOS:
        z = max(4, int(d / (rho * 3)))
        plan = make_plan(d, 3, z, seed=3)
        # Sketch only: server decodes the sketch it received
        rec = decompress(compress(h, plan), plan)
        cos, mse = _metrics(h, rec)
        rec_x = decompress(compress(x, plan), plan)
        rows.append((f"sketch_only", f"{rho}", cos, mse,
                     _token_acc(rec_x, toks, table)))
        for r in (8, 16):
            # U_n from the client's own recent hidden states (Eq. 17):
            # activations are anisotropic, so the top-r subspace carries
            # most of the energy and the secret rotation destroys it
            ss = make_ssop(h.reshape(-1, d), r, "secret-salt", 7)
            hh = apply_ssop(h, ss)
            rec = decompress(compress(hh, plan), plan)  # no V_n -> no inverse
            cos, mse = _metrics(h, rec)
            ss_x = make_ssop(x.reshape(-1, d), r, "secret-salt", 7)
            xx = apply_ssop(x, ss_x)
            rec_x = decompress(compress(xx, plan), plan)
            rows.append((f"elsa_r{r}", f"{rho}", cos, mse,
                         _token_acc(rec_x, toks, table)))
    for name, rho, cos, mse, acc in rows:
        emit(f"table6_{name}_rho{rho}", 0.0,
             f"cos={cos:.4f} mse={mse:.4f} token_acc={acc:.4f}")
    return rows


if __name__ == "__main__":
    run()
