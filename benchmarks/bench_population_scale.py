"""Population-scale study: round cost vs registered population size.

The registry-backed population (docs/population.md) promises that
per-round cost scales with the *cohort* (the federation's ``n_clients``
slots), not the *registered population*: sampling is O(cohort) Floyd
draws, per-client state lives in preallocated array columns, and the
LoRA adapter column allocates lazily in row-block shards, so growing
the population 1000x at a fixed cohort should leave round wall time
flat and registry memory dominated by the clients that actually
trained.

This bench runs the same fixed-cohort federation (8 slots, ``fedavg``,
sync loop) against populations from 10^2 up to 10^5 registered clients
and records, per population size:

- **round_s**: steady-state mean wall seconds per global round, summed
  from the telemetry round spans (round 0 is excluded — it holds the
  jit compiles);
- **registry_mib**: resident registry bytes after the run (scalar
  columns + allocated adapter shards only — the lazy-allocation
  contract);
- cohort/eligible/sampled counts from the ``population.*`` gauges.

Headline gate metric (``check_regression.py``): the round-time ratio
``round_s_small_over_large`` between the 10^2 and 10^4 populations —
flat-to-sublinear scaling keeps it near 1.0; a registry that silently
goes O(N) per round drags it toward 0.

A second arm re-runs the *largest* population with the SS-OP privacy
channel enabled.  Identity-keyed channels live in a bounded LRU on the
population runtime (docs/population.md): with a cohort streaming fresh
identities every round, nearly every dispatch misses the cache and
regenerates its rotation (one seeded QR against the shared reference
basis).  ``round_s_nochannel_over_channel`` gates that regeneration
cost (``population_channel_overhead``): cheap per-identity rotations
keep the ratio near 1.0; a regeneration blowup (e.g. a per-miss SVD or
probe forward) drags it toward 0.
"""
import os

from benchmarks.common import bench_telemetry, emit, write_json
from repro import telemetry as tm
from repro.federation.simulation import FedConfig, Federation
from repro.population import PopulationConfig

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_population_scale.json")

# the fault-tolerance bench's reduced encoder federation, minus the
# convergence tuning (this bench measures mechanics, not accuracy)
BASE = dict(n_clients=8, n_edges=2, alpha=5.0, poisoned=(),
            total_examples=800, probe_q=8, local_warmup_steps=2,
            layers=4, t_rounds=1, batch_size=16, seed=0, seq_len=32,
            num_classes=4, use_channel=False, pooling="mean")

ROUNDS, STEPS = 5, 2
POPULATIONS = (100, 1_000, 10_000, 100_000)
QUICK_POPULATIONS = (100, 10_000)

#: small shards + half precision keep the lazily-allocated adapter
#: column tiny even when every round touches a fresh cohort
SHARD_ROWS = 8
ADAPTER_DTYPE = "float16"


def _run_one(registered: int, rounds: int, tel, **overrides) -> dict:
    fed = Federation(FedConfig(**{**BASE, **overrides}), backend="batched")
    pop_cfg = PopulationConfig(registered=registered, seed=17,
                               shard_rows=SHARD_ROWS,
                               adapter_dtype=ADAPTER_DTYPE)
    base_rounds = len(tel.rounds)
    hist = fed.run("fedavg", global_rounds=rounds, steps_per_round=STEPS,
                   population=pop_cfg)
    recs = tel.rounds[base_rounds:]
    # steady-state rounds only: round 0 carries the jit compiles (and
    # the engine warm-up), which would swamp the scaling signal
    steady = [sum(s.get("dur_s", 0.0) for s in r["spans"])
              for r in recs[1:]]
    reg = fed._population.registry
    return {
        "registered": registered,
        "cohort": BASE["n_clients"],
        "rounds_timed": len(steady),
        "round_s": sum(steady) / max(len(steady), 1),
        "round_s_first": sum(s.get("dur_s", 0.0)
                             for s in recs[0]["spans"]) if recs else 0.0,
        "registry_mib": reg.nbytes / 2**20,
        "adapter_shards_allocated": reg.allocated_shards,
        "adapter_shards_total": reg.n_shards,
        "eligible": int(tel.gauge("population.eligible") or 0),
        "sampled": int(tel.gauge("population.sampled") or 0),
        "channel_cache_hits": int(
            tel.gauge("population.channel_cache_hits") or 0),
        "channel_cache_misses": int(
            tel.gauge("population.channel_cache_misses") or 0),
        "final_accuracy": float(hist["final_accuracy"]),
    }


def run(quick: bool = False, write: bool = True, out: str = None):
    rounds = 3 if quick else ROUNDS
    pops = QUICK_POPULATIONS if quick else POPULATIONS
    out_path = os.path.abspath(out or OUT_PATH)
    results = {}
    with bench_telemetry("population_scale", out_path if write else None,
                         rounds=rounds, quick=quick) as tel:
        for n in pops:
            r = _run_one(n, rounds, tel)
            results[str(n)] = r
            emit(f"population_scale_{n}", r["round_s"] * 1e6,
                 f"round_s={r['round_s']:.3f} "
                 f"registry_mib={r['registry_mib']:.2f} "
                 f"shards={r['adapter_shards_allocated']}"
                 f"/{r['adapter_shards_total']}")
        # channel-overhead arm: the largest population again, SS-OP
        # channel on — each fresh identity's rotation is an LRU miss
        channel = _run_one(pops[-1], rounds, tel, use_channel=True)
        emit(f"population_channel_{pops[-1]}", channel["round_s"] * 1e6,
             f"round_s={channel['round_s']:.3f} "
             f"cache_misses={channel['channel_cache_misses']} "
             f"cache_hits={channel['channel_cache_hits']}")

    # flatness gate between the 10^2 and 10^4 arms (present in both
    # modes): flat scaling -> ratio ~1, O(N) rot -> ratio -> 0
    small = results["100"]["round_s"]
    large = results["10000"]["round_s"]
    nochannel = results[str(pops[-1])]["round_s"]
    payload = {
        "config": {**{k: (list(v) if isinstance(v, tuple) else v)
                      for k, v in BASE.items()},
                   "rounds": rounds, "steps": STEPS,
                   "shard_rows": SHARD_ROWS,
                   "adapter_dtype": ADAPTER_DTYPE, "quick": quick},
        "populations": results,
        "channel_arm": channel,
        "round_s_small_over_large": round(small / max(large, 1e-12), 4),
        "round_s_ratio_large_over_small": round(large / max(small, 1e-12),
                                                4),
        "round_s_nochannel_over_channel": round(
            nochannel / max(channel["round_s"], 1e-12), 4),
        "max_registry_mib": round(max(r["registry_mib"]
                                      for r in results.values()), 3),
    }
    if write:
        write_json(out_path, payload)
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="two populations, shortened horizon (CI gate; "
                         "no BENCH json unless --out is given)")
    ap.add_argument("--out", default=None,
                    help="write the bench JSON here (CI regression gate)")
    args = ap.parse_args()
    print(run(quick=args.quick, write=args.out is not None or not args.quick,
              out=args.out))
