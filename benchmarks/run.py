"""Benchmark harness: the single entry point over every bench script.

Runs the suite (or a ``--only`` subset), printing the usual
``name,us_per_call,derived`` CSV, then merges everything one run
produced — each ``BENCH_*.json`` record plus the summary line of its
telemetry sidecar (``*.telemetry.jsonl``, docs/observability.md) — into
one ``BENCH_manifest.json`` run manifest: per-bench status/duration,
the full records, and the merged telemetry summaries.

Usage::

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME] \\
        [--out-dir DIR]

``--out-dir`` redirects every fresh record (and the manifest) into a
directory — the CI bench-gate shape, where the directory is both the
regression-gate input and the uploaded artifact.  Without it, full-mode
records land at the repo root as always and the manifest beside them.
"""
import argparse
import glob
import inspect
import json
import os
import sys
import time

from repro.telemetry import read_jsonl

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

BENCHES = [
    ("fig2_clustering", "benchmarks.bench_clustering"),
    ("table2_accuracy", "benchmarks.bench_accuracy"),
    ("table3_comm_time", "benchmarks.bench_comm_time"),
    ("table4_compression", "benchmarks.bench_compression"),
    ("table5_splitting", "benchmarks.bench_splitting"),
    ("table6_privacy", "benchmarks.bench_privacy"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.bench_roofline"),
    ("fed_round", "benchmarks.bench_fed_round"),
    ("sharded_round", "benchmarks.bench_sharded_round"),
    ("convergence", "benchmarks.bench_convergence"),
    ("time_to_accuracy", "benchmarks.bench_time_to_accuracy"),
    ("fault_tolerance", "benchmarks.bench_fault_tolerance"),
    ("population_scale", "benchmarks.bench_population_scale"),
]


def _run_kwargs(fn, quick: bool, out_dir: str, mod) -> dict:
    """The kwargs this bench's ``run()`` actually accepts: quick mode
    where supported, and the fresh record redirected into ``out_dir``
    (keeping each script's own BENCH filename)."""
    params = inspect.signature(fn).parameters
    kw = {}
    if quick and "quick" in params:
        kw["quick"] = True
    if out_dir and "out" in params:
        default = getattr(mod, "OUT_PATH", None)
        if default is not None:
            kw["out"] = os.path.join(out_dir,
                                     os.path.basename(default))
            if "write" in params:
                kw["write"] = True
    return kw


def merge_manifest(out_dir: str, benches: dict) -> dict:
    """Fold every ``BENCH_*.json`` in ``out_dir`` (+ its telemetry
    sidecar's summary line, when present) into one manifest dict."""
    records, telemetry = {}, {}
    for path in sorted(glob.glob(os.path.join(out_dir, "BENCH_*.json"))):
        fname = os.path.basename(path)
        if fname == "BENCH_manifest.json":
            continue
        try:
            with open(path) as f:
                records[fname] = json.load(f)
        except (OSError, ValueError) as e:
            records[fname] = {"error": f"{type(e).__name__}: {e}"}
            continue
        sidecar = path[:-5] + ".telemetry.jsonl"
        if os.path.exists(sidecar):
            telemetry[fname] = read_jsonl(sidecar)["summary"]
    return {"benches": benches, "records": records,
            "telemetry": telemetry}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only benches whose name contains this")
    ap.add_argument("--quick", action="store_true",
                    help="pass quick=True to benches that support it")
    ap.add_argument("--out-dir", default=None,
                    help="directory for fresh BENCH_*.json records + "
                         "the merged BENCH_manifest.json (default: "
                         "records go to the repo root)")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir) if args.out_dir else None
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)

    benches = {}
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, module in BENCHES:
        if args.only and args.only not in name:
            continue
        mod = __import__(module, fromlist=["run"])
        t_b = time.time()
        try:
            mod.run(**_run_kwargs(mod.run, args.quick, out_dir, mod))
            status = "ok"
        except Exception as e:  # noqa: BLE001
            status = f"ERROR:{type(e).__name__}:{e}"
            print(f"{name},0.0,{status}", file=sys.stderr)
            print(f"{name},0.0,ERROR:{type(e).__name__}")
        benches[name] = {"status": status,
                         "seconds": round(time.time() - t_b, 1)}
    print(f"# total {time.time()-t0:.1f}s")

    manifest = merge_manifest(out_dir or ROOT, benches)
    mpath = os.path.join(out_dir or ROOT, "BENCH_manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# manifest: {mpath} ({len(manifest['records'])} records, "
          f"{len(manifest['telemetry'])} telemetry summaries)")


if __name__ == '__main__':
    main()
