# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: python -m benchmarks.run [--only NAME]"""
import argparse
import sys
import time

BENCHES = [
    ("fig2_clustering", "benchmarks.bench_clustering"),
    ("table2_accuracy", "benchmarks.bench_accuracy"),
    ("table3_comm_time", "benchmarks.bench_comm_time"),
    ("table4_compression", "benchmarks.bench_compression"),
    ("table5_splitting", "benchmarks.bench_splitting"),
    ("table6_privacy", "benchmarks.bench_privacy"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.bench_roofline"),
    ("fed_round", "benchmarks.bench_fed_round"),
    ("time_to_accuracy", "benchmarks.bench_time_to_accuracy"),
    ("fault_tolerance", "benchmarks.bench_fault_tolerance"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, module in BENCHES:
        if args.only and args.only not in name:
            continue
        mod = __import__(module, fromlist=["run"])
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}", file=sys.stderr)
            print(f"{name},0.0,ERROR:{type(e).__name__}")
    print(f"# total {time.time()-t0:.1f}s")


if __name__ == '__main__':
    main()
