"""Bench regression gate: fail CI when a fresh bench run regresses.

Each gate reads one fresh ``BENCH_*.json`` (produced by a bench script's
``--out``) and checks a scalar metric against a floor:

- **absolute floors** hold on any runner (including quick-mode configs
  on a 2-core CI box): the batched engine must still beat the sequential
  reference, and sharding across host devices must never make a round
  catastrophically slower than unsharded;
- **committed-relative floors** (full mode only, ``--quick`` skips them
  because quick configs are not comparable): the fresh metric must
  retain a fraction of the committed record at the repo root.

Exit code 1 on any violation, so the CI job fails.  Usage::

    python benchmarks/check_regression.py --fresh DIR [--quick]
"""
import argparse
import json
import os
import sys
from typing import Callable, NamedTuple

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


class Gate(NamedTuple):
    name: str
    file: str
    metric: Callable[[dict], float]
    quick_floor: float      # absolute floor for --quick configs
    full_floor: float       # absolute floor for full configs
    committed_frac: float   # fresh >= frac * committed (full mode only;
                            # None skips — e.g. sign-indefinite metrics
                            # where a fraction of the record is not a
                            # meaningful floor)
    desc: str


GATES = (
    Gate("fed_round_speedup", "BENCH_fed_round.json",
         lambda p: p["speedup"],
         quick_floor=1.2, full_floor=3.0, committed_frac=0.6,
         desc="batched engine speedup over the sequential reference"),
    Gate("sharded_round_worst_speedup", "BENCH_sharded_round.json",
         lambda p: min(p["speedup_vs_unsharded"].values()),
         quick_floor=0.25, full_floor=0.35, committed_frac=0.5,
         desc="worst sharded-vs-unsharded round-time ratio across "
              "device counts (sharding must not cripple a round; CPU "
              "host devices share physical cores, so > 1x is not "
              "required)"),
    Gate("convergence_margin", "BENCH_convergence.json",
         lambda p: p["min_margin_over_chance"],
         quick_floor=0.05, full_floor=0.15, committed_frac=0.7,
         desc="worst tuned-stack test-accuracy margin over chance "
              "across model families (quick mode runs a shortened "
              "horizon, so its floor only guards against falling back "
              "to chance-level accuracy; the full floor is the "
              "tier-1 gate's chance+0.15 bar)"),
    Gate("fault_screening_advantage", "BENCH_fault_tolerance.json",
         lambda p: p["min_screened_advantage"],
         quick_floor=0.05, full_floor=0.10, committed_frac=0.5,
         desc="worst-case accuracy bought by update screening over "
              "unscreened aggregation under corrupted-client faults "
              "(screening must keep beating doing nothing)"),
    Gate("telemetry_overhead", "BENCH_fed_round.json",
         lambda p: p["telemetry_ratio"],
         quick_floor=0.95, full_floor=0.95, committed_frac=None,
         desc="telemetry-disabled / telemetry-enabled batched round "
              "time (the zero-overhead-when-collecting contract of "
              "docs/observability.md: an enabled round may cost at "
              "most ~5% wall time; the ratio hovers around 1.0 so no "
              "committed-relative floor applies)"),
    Gate("population_scale_flatness", "BENCH_population_scale.json",
         lambda p: p["round_s_small_over_large"],
         quick_floor=0.35, full_floor=0.5, committed_frac=None,
         desc="small-population / large-population steady round time "
              "at a fixed cohort (the registry's O(cohort) per-round "
              "contract of docs/population.md: flat scaling keeps the "
              "ratio near 1.0, an O(registered) regression drags it "
              "toward 0; timing noise makes a committed-relative "
              "floor too brittle)"),
    Gate("population_channel_overhead", "BENCH_population_scale.json",
         lambda p: p["round_s_nochannel_over_channel"],
         quick_floor=0.25, full_floor=0.4, committed_frac=None,
         desc="no-channel / with-channel steady round time at the "
              "largest population (identity-keyed SS-OP channels of "
              "docs/population.md: fresh cohorts miss the channel LRU "
              "nearly every round, so a rotation-regeneration blowup — "
              "a per-miss SVD or probe forward instead of a seeded "
              "QR — drags the ratio toward 0; timing noise makes a "
              "committed-relative floor too brittle)"),
    Gate("fault_screening_gap", "BENCH_fault_tolerance.json",
         lambda p: -p["max_screened_gap"],
         quick_floor=-0.10, full_floor=-0.05, committed_frac=None,
         desc="negated worst-case screened-vs-fault-free accuracy gap "
              "(screened runs must stay within 0.05 of the fault-free "
              "reference in full mode, 0.10 on the quick horizon; the "
              "metric is sign-indefinite so no committed-relative "
              "floor applies)"),
)


def check(fresh_dir: str, quick: bool, only: str = None) -> int:
    failures = 0
    gates = [g for g in GATES if only is None or only in g.name]
    if not gates:
        print(f"no gate matches --only {only!r}")
        return 1
    for g in gates:
        fresh_path = os.path.join(fresh_dir, g.file)
        if not os.path.exists(fresh_path):
            print(f"FAIL {g.name}: fresh record {fresh_path} missing "
                  "(did the bench step run with --out?)")
            failures += 1
            continue
        with open(fresh_path) as f:
            value = g.metric(json.load(f))
        floor = g.quick_floor if quick else g.full_floor
        committed_path = os.path.join(ROOT, g.file)
        if (not quick and g.committed_frac is not None
                and os.path.exists(committed_path)):
            with open(committed_path) as f:
                committed = g.metric(json.load(f))
            floor = max(floor, g.committed_frac * committed)
        ok = value >= floor
        print(f"{'ok  ' if ok else 'FAIL'} {g.name}: {value:.2f} "
              f"(floor {floor:.2f}{', quick' if quick else ''}) — "
              f"{g.desc}")
        failures += 0 if ok else 1
    return failures


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="directory holding freshly-produced BENCH_*.json")
    ap.add_argument("--quick", action="store_true",
                    help="fresh records come from --quick bench configs: "
                         "use the relaxed absolute floors and skip "
                         "committed-relative checks")
    ap.add_argument("--only", default=None,
                    help="check only gates whose name contains this "
                         "substring (for single-purpose CI jobs)")
    args = ap.parse_args()
    n = check(args.fresh, args.quick, args.only)
    if n:
        print(f"{n} bench regression gate(s) failed")
        sys.exit(1)
    print("all bench regression gates passed")
