"""Table IV reproduction: compression ratio rho vs fidelity and
communication benefit.

Fidelity proxy: (i) hidden-state reconstruction quality through the
sketch channel, (ii) downstream accuracy of a short federated run at two
rho levels.  The paper's qualitative claims: benefit grows with rho,
accuracy decays with rho, rho in [2.1, 4.2] is the sweet spot.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.sketch import make_plan, compress, decompress
from repro.federation.simulation import FedConfig, Federation

RHOS = (2.1, 3.3, 6.4, 8.4, 11.8)


def run(d=768, y=3, n=256, seed=0):
    h = jax.random.normal(jax.random.PRNGKey(seed), (n, d))

    def sweep():
        out = {}
        for rho in RHOS:
            z = max(4, int(d / (rho * y)))
            plan = make_plan(d, y, z, seed=1)
            rec = decompress(compress(h, plan), plan)
            rel = float(jnp.linalg.norm(rec - h) / jnp.linalg.norm(h))
            cos = float(jnp.mean(jnp.sum(rec * h, -1) /
                                 (jnp.linalg.norm(rec, axis=-1)
                                  * jnp.linalg.norm(h, axis=-1))))
            out[rho] = (d / (y * z), rel, cos)
        return out

    out, us = timeit(sweep, repeats=2)
    for rho, (rho_eff, rel, cos) in out.items():
        emit(f"table4_rho{rho}", us / len(RHOS),
             f"rho_eff={rho_eff:.2f} rel_err={rel:.3f} cos={cos:.3f} "
             f"comm_benefit={rho_eff:.2f}x")

    # accuracy at two rho levels (short runs)
    accs = {}
    for rho in (2.1, 8.4):
        fed = Federation(FedConfig(n_clients=8, n_edges=2, alpha=0.2,
                                   poisoned=(), total_examples=1600,
                                   probe_q=16, local_warmup_steps=4,
                                   lr=2e-2, rho=rho, layers=4,
                                   t_rounds=1))
        hist = fed.run("elsa", global_rounds=6, steps_per_round=6)
        accs[rho] = hist["final_accuracy"]
    emit("table4_accuracy_vs_rho", 0.0,
         " ".join(f"rho{r}={a:.4f}" for r, a in accs.items()))
    return out, accs


if __name__ == "__main__":
    run()
