"""Sharded federation round: wall-clock vs device count, fixed population.

Times one global ``fedavg`` round (all clients in one group, dynamic
splits and the SS-OP∘sketch channel active, no profiling phase) for the
batched engine unsharded and then sharded across meshes of 1, 2, 4, ...
devices (``Federation(backend="batched", mesh=make_federation_mesh(d))``)
at a *fixed* client population, so the curve isolates how the stacked
client axis scales across devices.  Each configuration gets one warmup
run (compiles, builds channels) before the timed run.

Must see multiple devices to measure anything: the module forces
``--xla_force_host_platform_device_count`` (default 8, override with
``BENCH_HOST_DEVICES``) into ``XLA_FLAGS`` *before* the first jax
import, so plain CPU hosts — laptops, CI runners — exercise the real
multi-device partitioning path.  Note host devices share the machine's
physical cores, so measured CPU "speedup" is bounded by core count, not
device count; the curve is still the regression signal CI gates on
(sharding must never make a round catastrophically slower).

Writes ``BENCH_sharded_round.json`` at the repo root (or ``--out``).
"""
import os

_FLAGS = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in _FLAGS:
    os.environ["XLA_FLAGS"] = (_FLAGS + " --xla_force_host_platform_"
                               "device_count="
                               + os.environ.get("BENCH_HOST_DEVICES", "8"))

import jax                                                    # noqa: E402

from benchmarks.common import (bench_telemetry, emit,         # noqa: E402
                               fed_round_config, time_fed_round,
                               write_json)
from repro.federation.simulation import FedConfig, Federation  # noqa: E402
from repro.launch.mesh import make_federation_mesh            # noqa: E402

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_sharded_round.json")


def _time_round(mesh, steps: int, cfg_kw: dict) -> float:
    return time_fed_round(
        lambda: Federation(FedConfig(**cfg_kw), backend="batched",
                           mesh=mesh), steps)


def run(steps: int = 4, clients: int = 64, model: str = "bert-base",
        device_counts=None, write: bool = True, out: str = None,
        quick: bool = False):
    n_avail = len(jax.devices())
    if quick:
        # CI smoke config; never clobber the committed full-run record
        steps, clients = 2, 16
        if device_counts is None:
            device_counts = sorted({1, n_avail})
        write = write and out is not None
    if device_counts is None:
        device_counts = [d for d in (1, 2, 4, 8, 16) if d <= n_avail]
    # population is the swept variable here, so the dataset scales with
    # it (50 examples/client) instead of bench_fed_round's fixed total
    cfg_kw = fed_round_config(clients, model, total_examples=50 * clients)
    out_path = os.path.abspath(out or OUT_PATH)
    # every config times under the same (enabled) telemetry condition,
    # so the gated speedup ratios stay apples-to-apples
    with bench_telemetry("sharded_round",
                         out_path if write else None,
                         clients=clients, model=model, steps=steps,
                         devices=n_avail):
        t_unsharded = _time_round(None, steps, cfg_kw)
        sharded, speedup = {}, {}
        for d in device_counts:
            t_d = _time_round(make_federation_mesh(d), steps, cfg_kw)
            sharded[str(d)] = round(t_d, 3)
            speedup[str(d)] = round(t_unsharded / t_d, 2)
            emit("sharded_round", t_d * 1e6,
                 f"{model}:{clients}c/{d}dev speedup={speedup[str(d)]}x")
    payload = {
        # labels come from the shared config so the record can't drift
        # from the measured workload
        "config": {"clients": clients, "steps_per_round": steps,
                   "model": model, "layers": cfg_kw["layers"],
                   "t_rounds": cfg_kw["t_rounds"],
                   "batch_size": cfg_kw["batch_size"], "method": "fedavg",
                   "devices_available": n_avail, "device": "cpu"},
        "unsharded_s": round(t_unsharded, 3),
        "sharded_s": sharded,
        "speedup_vs_unsharded": speedup,
    }
    if write:
        write_json(out_path, payload)
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny CI smoke configuration")
    ap.add_argument("--model", default="bert-base")
    ap.add_argument("--out", default=None,
                    help="write the bench JSON here (quick mode only "
                         "writes when --out is given)")
    args = ap.parse_args()
    print(run(model=args.model, out=args.out, quick=args.quick))
