"""Fig. 2 reproduction: 20-client behavioral KLD matrix + trust-aware
clustering; poisoned clients should be excluded or down-weighted."""
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import clustering as clus
from repro.core.fingerprint import divergence_matrix, fingerprint
from repro.core.trust import trust_scores
from repro.federation.simulation import FedConfig, Federation


def run():
    fed = Federation(FedConfig(n_clients=20, n_edges=4, alpha=0.1,
                               poisoned=(3, 8, 12, 17), total_examples=1200,
                               probe_q=24, local_warmup_steps=8,
                               layers=4))

    (div, trust, cres, _), us = timeit(fed.profile_clients, repeats=1,
                                       warmup=0)
    poisoned = set(fed.fed.poisoned)
    # poisoned clients should carry below-median trust
    med = float(np.median(trust))
    low_trust_poisoned = sum(1 for p in poisoned if trust[p] <= med)
    placed = {n for g in cres.groups.values() for n in g}
    excluded_or_escalated = set(range(20)) - placed
    caught = len(poisoned & excluded_or_escalated)
    emit("fig2_clustering", us,
         f"kld_range=[{div[div > 0].min():.1f};{div.max():.1f}]"
         f" low_trust_poisoned={low_trust_poisoned}/4"
         f" excluded_poisoned={caught}"
         f" groups={[len(g) for g in cres.groups.values()]}")
    return {"div": div, "trust": trust, "result": cres}


if __name__ == "__main__":
    run()
