"""Pallas kernel timings (interpret mode on CPU — correctness-path cost,
not TPU wall time) vs their pure-jnp oracles."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.sketch import make_plan
from repro.core.ssop import make_ssop
from repro.kernels.count_sketch import ops as cs_ops
from repro.kernels.count_sketch.ref import compress_ref
from repro.core.sketch import selection_matrices
from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_bhsd_ref
from repro.kernels.lora import ops as lora_ops
from repro.kernels.lora.ref import lora_matmul_ref
from repro.kernels.ssop import ops as ssop_ops
from repro.kernels.ssop.ref import ssop_apply_ref


def run():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (8, 256, 64))
    k = jax.random.normal(key, (2, 256, 64))
    _, us_k = timeit(lambda: jax.block_until_ready(
        flash_attention_bhsd(q, k, k, bq=128, bk=128)), repeats=3)
    _, us_r = timeit(lambda: jax.block_until_ready(
        attention_bhsd_ref(q, k, k)), repeats=3)
    emit("kernel_flash_attention", us_k, f"ref_us={us_r:.1f}")

    h = jax.random.normal(key, (256, 512))
    plan = make_plan(512, 3, 64, seed=1)
    s = selection_matrices(plan)
    _, us_k = timeit(lambda: jax.block_until_ready(
        cs_ops.sketch_compress(h, plan)), repeats=3)
    _, us_r = timeit(lambda: jax.block_until_ready(
        compress_ref(h, s)), repeats=3)
    emit("kernel_count_sketch", us_k, f"ref_us={us_r:.1f}")

    ss = make_ssop(jax.random.normal(key, (64, 512)), 16, "s", 0)
    _, us_k = timeit(lambda: jax.block_until_ready(
        ssop_ops.ssop_apply(h, ss.u, ss.v)), repeats=3)
    w = ss.v.T - jnp.eye(16)
    _, us_r = timeit(lambda: jax.block_until_ready(
        ssop_apply_ref(h, ss.u, w)), repeats=3)
    emit("kernel_ssop", us_k, f"ref_us={us_r:.1f}")

    x = jax.random.normal(key, (256, 512))
    wte = jax.random.normal(key, (512, 512)) * 0.05
    a = jax.random.normal(key, (512, 16)) * 0.05
    b = jax.random.normal(key, (16, 512)) * 0.05
    _, us_k = timeit(lambda: jax.block_until_ready(
        lora_ops.lora_matmul(x, wte, a, b, 2.0)), repeats=3)
    _, us_r = timeit(lambda: jax.block_until_ready(
        lora_matmul_ref(x, wte, a, b, 2.0)), repeats=3)
    emit("kernel_lora_matmul", us_k, f"ref_us={us_r:.1f}")


if __name__ == "__main__":
    run()
