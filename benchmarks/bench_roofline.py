"""Roofline summary over saved dry-run artifacts (EXPERIMENTS.md §Roofline
source data): per (arch x shape), the three terms and the dominant one."""
import glob
import os

from benchmarks.common import emit
from repro.analysis.roofline import load_record, roofline_terms

RUNS = os.path.join(os.path.dirname(__file__), "..", "runs", "dryrun")


def run(mesh="pod256"):
    rows = []
    for path in sorted(glob.glob(os.path.join(RUNS, "*.json"))):
        rec = load_record(path)
        if not rec or rec.get("mesh") != mesh or rec.get("tag"):
            continue
        if rec["status"] != "ok":
            emit(f"roofline_{rec['arch']}_{rec['shape']}", 0.0,
                 rec["status"])
            continue
        t = roofline_terms(rec)
        emit(f"roofline_{rec['arch']}_{rec['shape']}", 0.0,
             f"compute_s={t['compute_s']:.3f} memory_s={t['memory_s']:.3f} "
             f"collective_s={t['collective_s']:.3f} dom={t['dominant']} "
             f"6ND/HLO={t['useful_ratio']:.3f}")
        rows.append((rec["arch"], rec["shape"], t))
    return rows


if __name__ == "__main__":
    run()
