"""Time-to-target under churn + constrained devices: sync vs deadline
vs async edge scheduling.

The ISSUE-2 acceptance scenario: 20 clients / 4 edges on a reduced BERT
(CPU), 30% of devices compute-constrained (``constrained_frac``) and half
the population cycling offline/online on an exponential churn trace.  All
three policies run the *same* federation (same data, same splits, same
compiled BatchedEngine); only the simulated schedule differs.  The
barrier in ``sync`` pays the slowest straggler (churn pauses included)
every edge round, so ``deadline`` (bounded rounds, straggler carry-over)
and ``async`` (continuous staleness-weighted folding) reach the same
training progress in less simulated wall-clock.

Target metrics (both are first-crossing times on the simulated clock):

- **primary: training-loss target** — fixed at 1.01x the *worst*
  policy's best achieved mean training loss, so every policy provably
  crosses it and the crossing reflects actual optimization progress.
- **secondary: accuracy target** — chance + 0.08, reported only when a
  policy's test-accuracy curve actually clears it.  On this repo's
  offline synthetic corpus the reduced-BERT + SGD stack plateaus at
  chance-level *test* accuracy for every method and scheduler (the same
  caveat as ``bench_accuracy``: absolute accuracies are not comparable
  to the paper; see ROADMAP), so this is typically ``null`` — it is
  emitted instead of silently lowering the bar to eval cadence.

Writes ``BENCH_time_to_accuracy.json`` at the repo root; ``--quick``
shrinks everything for the CI smoke step and skips the JSON (it must
not clobber the committed full-run artifact).
"""
import argparse
import os

import numpy as np

from benchmarks.common import bench_telemetry, emit, write_json
from repro.federation.simulation import FedConfig, Federation
from repro.federation.topology import make_churn_trace
from repro.runtime import RuntimeConfig

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_time_to_accuracy.json")
POLICIES = ("sync", "deadline", "async")


def _scenario(quick: bool):
    if quick:
        fed = dict(n_clients=6, n_edges=2, alpha=0.2, poisoned=(4,),
                   total_examples=600, probe_q=8, local_warmup_steps=2,
                   layers=4, lr=2e-2, t_rounds=1, batch_size=16,
                   constrained_frac=0.34, seed=0)
        run = dict(global_rounds=3, steps_per_round=2)
        churn = dict(mean_on_s=40.0, mean_off_s=15.0, churn_frac=0.5,
                     seed=7)
    else:
        fed = dict(n_clients=20, n_edges=4, alpha=0.1,
                   poisoned=(3, 8, 12, 17), total_examples=2000,
                   probe_q=16, local_warmup_steps=2, layers=4,
                   lr=2e-2, t_rounds=1, batch_size=16,
                   constrained_frac=0.3, seed=0)
        run = dict(global_rounds=8, steps_per_round=4)
        churn = dict(mean_on_s=30.0, mean_off_s=12.0, churn_frac=0.5,
                     seed=7)
    return fed, run, churn


def _first_crossing(times, values, target, *, below: bool):
    for t, v in zip(times, values):
        if (v <= target) if below else (v >= target):
            return float(t)
    return None


def run(quick: bool = False, method: str = "elsa-nocluster"):
    fed_kw, run_kw, churn_kw = _scenario(quick)
    churn = make_churn_trace(fed_kw["n_clients"], 1e6, **churn_kw)

    results = {}
    # CI smoke must not clobber the committed artifact, telemetry
    # sidecar included
    tel_json = None if quick else os.path.abspath(OUT_PATH)
    with bench_telemetry("time_to_accuracy", tel_json, method=method,
                         quick=quick):
        for policy in POLICIES:
            fed = Federation(FedConfig(**fed_kw))
            h = fed.run(method, eval_every=1,
                        runtime=RuntimeConfig(policy=policy, churn=churn),
                        **run_kw)
            results[policy] = h
            emit(f"tta_{policy}_sim_s", h["time"][-1] * 1e6,
                 f"final_acc={h['final_accuracy']:.4f} "
                 f"final_loss={h['loss'][-1]:.4f} "
                 f"rounds={len(h['round'])} trace={h['trace'].summary()}")

    # primary: the worst policy's best achieved training loss, +1% slack,
    # is reachable by every policy — crossing time measures optimization
    # progress on the simulated clock, not eval cadence
    loss_target = 1.01 * max(min(h["loss"]) for h in results.values())
    # secondary: accuracy must clear chance by a margin to count at all
    chance = 1.0 / FedConfig(**fed_kw).num_classes
    acc_target = chance + 0.08

    payload = {
        "config": {**fed_kw, **run_kw, "method": method,
                   "churn": churn_kw, "device": "cpu",
                   "quick": bool(quick)},
        "loss_target": round(loss_target, 6),
        "accuracy_target": round(acc_target, 6),
        "chance_accuracy": round(chance, 6),
        "note": ("loss crossing is the primary metric: the offline "
                 "synthetic corpus + reduced-BERT SGD stack plateaus at "
                 "chance-level test accuracy for every method/scheduler "
                 "(see ROADMAP open item), so accuracy crossings are "
                 "null rather than cadence artifacts"),
        "policies": {},
    }
    t_sync = None
    for policy, h in results.items():
        tl = _first_crossing(h["time"], h["loss"], loss_target, below=True)
        ta = _first_crossing(h["time"], h["accuracy"], acc_target,
                             below=False)
        if policy == "sync":
            t_sync = tl
        payload["policies"][policy] = {
            "time_to_loss_target_s": None if tl is None else round(tl, 3),
            "time_to_accuracy_target_s": (None if ta is None
                                          else round(ta, 3)),
            "sim_time_s": round(h["time"][-1], 3),
            "final_accuracy": round(h["final_accuracy"], 6),
            "final_loss": round(h["loss"][-1], 6),
            "loss": [round(l, 6) for l in h["loss"]],
            "accuracy": [round(a, 6) for a in h["accuracy"]],
            "time": [round(t, 3) for t in h["time"]],
            "trace": h["trace"].summary(),
        }
    for policy in ("deadline", "async"):
        tl = payload["policies"][policy]["time_to_loss_target_s"]
        speedup = (round(t_sync / tl, 3)
                   if tl not in (None, 0.0) and t_sync else None)
        payload["policies"][policy]["speedup_vs_sync"] = speedup
        emit(f"tta_{policy}_speedup", 0.0,
             f"time_to_loss_{loss_target:.3f}: sync={t_sync} "
             f"{policy}={tl} speedup={speedup}")
    if not quick:   # CI smoke must not clobber the committed artifact
        write_json(os.path.abspath(OUT_PATH), payload)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny CI smoke configuration (no BENCH json)")
    ap.add_argument("--method", default="elsa-nocluster")
    args = ap.parse_args()
    out = run(quick=args.quick, method=args.method)
    for p, row in out["policies"].items():
        print(p, "loss_t:", row["time_to_loss_target_s"],
              "acc_t:", row["time_to_accuracy_target_s"],
              "speedup:", row.get("speedup_vs_sync"))
