"""Privacy attack demo (Table VI threat model): a semi-honest edge server
tries to reconstruct client activations and identify input tokens from
the uplink payload, under four protection levels.

  PYTHONPATH=src python examples/privacy_attack_demo.py
"""
import sys

sys.path.insert(0, ".")

from benchmarks.bench_privacy import run  # noqa: E402


if __name__ == "__main__":
    print("protection            rho    cos     mse     token-id acc")
    rows = run()
    for name, rho, cos, mse, acc in rows:
        print(f"{name:20s} {rho:>5s} {cos:7.4f} {mse:7.3f} {acc:7.4f}")
    print("\nELSA (SS-OP + sketch) lowers reconstruction cosine and token")
    print("identification below sketch-only at every rho; r=16 > r=8.")
