"""End-to-end ELSA driver (Alg. 1): behavior-aware clustering ->
dynamic-split LoRA fine-tuning through the SS-OP∘sketch channel ->
coherence/trust-weighted cloud fusion, with checkpointing.

  PYTHONPATH=src python examples/elsa_federated_finetune.py \
      [--rounds 10] [--clients 20] [--method elsa] [--full] \
      [--model bert-base|llama3-8b|...] [--backend batched|reference]

--full uses the paper's 20-client / 4-edge / 8-layer setup (slow on CPU);
the default is a reduced config that finishes in a few minutes.

--model picks any architecture registered in the SplitModel registry
(docs/models.md): the paper's "bert-base" encoder by default, or a
dense causal LM ("llama3-8b", "qwen2.5-3b", "olmo-1b", "qwen1.5-4b")
trained with next-token CE on the same synthetic corpus.

--backend batched (default) runs local training through the compiled
vmap/scan federation engine (clients stacked per split bucket, one
compiled round per configuration); --backend reference keeps the
sequential one-client-at-a-time loop for comparison.

--tuned applies the convergence stack (docs/convergence.md): per-client
global-norm clipping, per-group lrs, mean-pool readout (encoders), and
a bias-corrected FedAdam server step on an easier task configuration;
--aggregate product|factor selects the LoRA aggregation space
(weight-delta mean vs legacy leafwise factor averaging).
"""
import argparse
import os

from repro.checkpoint import save
from repro.federation.simulation import FedConfig, Federation


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="elsa",
                    choices=["elsa", "elsa-fixed", "elsa-nocluster",
                             "fedavg", "fedavg-random", "fedprox", "fedams"])
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--edges", type=int, default=3)
    ap.add_argument("--alpha", type=float, default=None,
                    help="Dirichlet label-skew concentration (default "
                         "0.1; --tuned defaults to its studied 5.0 "
                         "unless you pass one explicitly)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--model", default="bert-base",
                    help="registered split-model name (see docs/models.md)")
    ap.add_argument("--backend", default="batched",
                    choices=["batched", "reference"])
    ap.add_argument("--aggregate", default="product",
                    choices=["product", "factor"],
                    help="LoRA aggregation space (docs/convergence.md)")
    ap.add_argument("--tuned", action="store_true",
                    help="convergence stack: clipping, per-group lrs, "
                         "mean-pool readout, FedAdam server step")
    ap.add_argument("--out", default="runs/elsa_finetune")
    args = ap.parse_args()

    # --tuned defaults alpha to the studied 5.0; an explicit --alpha
    # always wins (so the tuned stack can be stressed under any skew)
    alpha = args.alpha if args.alpha is not None \
        else (5.0 if args.tuned else 0.1)
    if args.full:
        kw = dict(n_clients=20, n_edges=4, alpha=alpha,
                  poisoned=(3, 8, 12, 17), total_examples=4000,
                  layers=8, lr=2e-2, t_rounds=2, model=args.model)
    else:
        kw = dict(n_clients=args.clients, n_edges=args.edges,
                  alpha=alpha, poisoned=(2,),
                  total_examples=1500, probe_q=16,
                  local_warmup_steps=4, layers=4, lr=2e-2,
                  t_rounds=1, model=args.model)
    kw["aggregate"] = args.aggregate
    if args.tuned:
        lm = args.model != "bert-base"
        kw.update(clip_norm=1.0, seq_len=32,
                  class_sharpness=10.0, background_frac=0.0,
                  server_opt="fedadam", server_lr=0.03)
        kw.update(dict(lr=0.5, vocab_size=32) if lm
                  else dict(lr=5e-3, head_lr=0.4, pooling="mean"))
    cfg = FedConfig(**kw)
    fed = Federation(cfg, backend=args.backend)

    print(f"== phase 1: profiling {cfg.n_clients} clients ==")
    div, trust, cres, _ = fed.profile_clients()
    for k, members in cres.groups.items():
        if members:
            print(f"  edge {k}: clients {members} "
                  f"(mean trust {trust[members].mean():.3f})")
    if cres.escalated:
        print(f"  escalated to cloud: {cres.escalated}")
    if cres.excluded:
        print(f"  excluded: {cres.excluded}")

    print(f"== phases 2-3: {args.method} for {args.rounds} rounds ==")
    hist = fed.run(args.method, global_rounds=args.rounds,
                   steps_per_round=args.steps, log=True)

    os.makedirs(args.out, exist_ok=True)
    scalar_hist = {k: list(map(float, v)) if isinstance(v, list)
                   else float(v) for k, v in hist.items()
                   if isinstance(v, (list, int, float))}
    save(os.path.join(args.out, f"{args.method}_history.msgpack"),
         scalar_hist)
    print(f"final accuracy: {hist['final_accuracy']:.4f} "
          f"(history -> {args.out})")


if __name__ == "__main__":
    main()
