"""Quickstart: the three ELSA mechanisms in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import cluster_clients
from repro.core.sketch import compress, decompress, make_plan
from repro.core.splitting import SplitPolicy, splits_for_population
from repro.core.ssop import apply_ssop, apply_ssop_inverse, make_ssop

# 1. behavior-aware clustering (Eqs. 4-6 + Stages 1-4) ----------------------
rng = np.random.default_rng(0)
n_clients, n_edges = 12, 3
div = np.abs(rng.normal(5.0, 0.5, (n_clients, n_clients)))
div = (div + div.T) / 2
np.fill_diagonal(div, 0)
for g in range(3):                       # three behaviorally-tight groups
    idx = np.arange(4 * g, 4 * g + 4)
    div[np.ix_(idx, idx)] *= 0.02
trust = np.ones(n_clients)
trust[7] = 0.05                          # a poisoned client
latency = np.full((n_clients, n_edges), 500.0)
for g in range(3):
    latency[4 * g:4 * g + 4, g] = 30.0
result = cluster_clients(div, trust, latency, tau_max=200.0, w_min=0.3)
print("clusters:", {k: v for k, v in result.groups.items()})
print("excluded (low trust / out of range):",
      result.excluded + result.escalated)

# 2. resource-aware dynamic splitting (Eqs. 7-9) ----------------------------
policy = SplitPolicy(num_blocks=12, o_fix=2, p_min=1, p_max=6)
splits = splits_for_population(
    capacities=[1e9, 5e10, 1e12], bandwidths=[1e8, 5e6, 1e6], policy=policy)
print("splits (p, q, o) for weak/mid/strong clients:", splits)

# 3. SS-OP + count-sketch channel (Eqs. 17-21) ------------------------------
d = 256
h = jax.random.normal(jax.random.PRNGKey(0), (32, d))     # hidden states
ssop = make_ssop(h, r=8, salt="secret", client_id=3)
plan = make_plan(d, y=3, z=40, seed=1)                    # rho ~ 2.1
wire = compress(apply_ssop(h, ssop), plan)                # what is sent
print(f"wire payload: {wire.shape} ({h.size / wire.size:.2f}x smaller)")
h_rec = apply_ssop_inverse(decompress(wire, plan), ssop)  # receiver side
rel = float(jnp.linalg.norm(h_rec - h) / jnp.linalg.norm(h))
print(f"round-trip relative error (sketch noise only): {rel:.3f}")
# an eavesdropper without V_n cannot undo the rotation:
leak = decompress(wire, plan)
cos = float(jnp.mean(jnp.sum(leak * h, -1) /
                     (jnp.linalg.norm(leak, axis=-1)
                      * jnp.linalg.norm(h, axis=-1))))
print(f"eavesdropper cosine similarity: {cos:.3f}")
