"""Wall-clock federation on the event-driven edge runtime.

Runs the same reduced-BERT federation under one or all scheduler
policies and prints accuracy-vs-simulated-time, per-policy event
statistics, and (with ``--policy all``) the time-to-accuracy comparison:

  PYTHONPATH=src python examples/async_edge_runtime.py \
      [--policy all|sync|deadline|async] [--method elsa-nocluster] \
      [--clients 10] [--rounds 4] [--churn] [--constrained 0.3]

``--churn`` switches on the dropout/rejoin availability model; with
``--constrained`` a fraction of devices gets throttled compute+uplink
(the paper's heterogeneous-device setup).  Try ``--policy all --churn``
to watch sync pay the straggler barrier while deadline/async don't.
"""
import argparse

from repro.federation.simulation import FedConfig, Federation
from repro.federation.topology import make_churn_trace
from repro.runtime import RuntimeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="all",
                    choices=["all", "sync", "deadline", "async"])
    ap.add_argument("--method", default="elsa-nocluster")
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--edges", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--churn", action="store_true")
    ap.add_argument("--constrained", type=float, default=0.3)
    args = ap.parse_args()

    fed_kw = dict(n_clients=args.clients, n_edges=args.edges, alpha=0.2,
                  poisoned=(2,), total_examples=1500, probe_q=16,
                  local_warmup_steps=4, layers=4, lr=2e-2,
                  t_rounds=1, constrained_frac=args.constrained)
    churn = None
    if args.churn:
        churn = make_churn_trace(args.clients, 1e6, mean_on_s=30.0,
                                 mean_off_s=12.0, churn_frac=0.5, seed=7)

    policies = (["sync", "deadline", "async"] if args.policy == "all"
                else [args.policy])
    curves = {}
    for policy in policies:
        fed = Federation(FedConfig(**fed_kw))
        h = fed.run(args.method, global_rounds=args.rounds,
                    steps_per_round=args.steps,
                    runtime=RuntimeConfig(policy=policy, churn=churn))
        curves[policy] = h
        print(f"\n== {policy} ==  (trace: {h['trace'].summary()})")
        print(f"  {'sim time':>10}  {'accuracy':>8}  {'loss':>8}")
        for t, a, l in zip(h["time"], h["accuracy"], h["loss"]):
            print(f"  {t:9.1f}s  {a:8.4f}  {l:8.4f}")

    if len(curves) > 1:
        # training-loss crossing: the honest progress-per-simulated-second
        # metric here (test accuracy plateaus at chance on the offline
        # synthetic corpus — see bench_time_to_accuracy / ROADMAP)
        target = 1.01 * max(min(h["loss"]) for h in curves.values())
        print(f"\n== time to training loss {target:.4f} ==")
        for policy, h in curves.items():
            tt = next((t for t, l in zip(h["time"], h["loss"])
                       if l <= target), None)
            print(f"  {policy:9s} {'—' if tt is None else f'{tt:9.1f}s'}")


if __name__ == "__main__":
    main()
