"""Serving-path demo: batched greedy decoding with a KV cache on a
reduced assigned architecture (the serve_step lowered by the decode
dry-run shapes).

  PYTHONPATH=src python examples/serve_demo.py [--arch llama3-8b] [--steps 12]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_config
from repro.launch.train import make_serve_step
from repro.models import zoo
from repro.models.params import init_tree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=ASSIGNED)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = zoo.get_model(cfg)
    if model.decode_step is None:
        raise SystemExit(f"{args.arch} has no decode path")
    params = init_tree(model.specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    cache = init_tree(model.cache_specs(cfg, args.batch, 64),
                      jax.random.PRNGKey(1), jnp.float32)
    serve = jax.jit(make_serve_step(cfg))

    tok = jax.random.randint(jax.random.PRNGKey(2), (args.batch, 1), 0,
                             cfg.vocab_size)
    print(f"{args.arch} (reduced: {cfg.num_layers}L d={cfg.d_model}) "
          f"decoding {args.steps} tokens for batch={args.batch}")
    seqs = [tok[:, 0]]
    for t in range(args.steps):
        nxt, cache = serve(params["frozen"], params["lora"], cache,
                           {"tokens": tok})
        tok = nxt[:, None]
        seqs.append(nxt)
    out = jnp.stack(seqs, 1)
    for b in range(args.batch):
        print(f"  seq[{b}]: {out[b].tolist()}")


if __name__ == "__main__":
    main()
