"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, 7 of 8 blocks) and
sLSTM (scalar memory with recurrent weights, 1 of 8).

mLSTM train/prefill uses a chunkwise form: ``lax.scan`` over chunks with the
stabilized intra-chunk interaction computed attention-style
((B,H,L,L) decay-masked score matrices).  Decode is the exact stabilized
recurrence on (C, n, m).  sLSTM is inherently sequential -> ``lax.scan``
over time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import Spec
from repro.models.common import apply_norm, NEG_INF


def _inner(cfg):
    return int(cfg.ssm.proj_factor * cfg.d_model)


def _heads(cfg):
    return cfg.num_heads


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def mlstm_specs(cfg):
    d, di, h = cfg.d_model, _inner(cfg), _heads(cfg)
    k = cfg.ssm.conv_kernel
    return {
        "ln": {"scale": Spec((d,), ("embed",), "ones"),
               "bias": Spec((d,), ("embed",), "zeros")},
        "w_x": Spec((d, di), ("embed", "mlp")),
        "w_z": Spec((d, di), ("embed", "mlp")),
        "conv_w": Spec((k, di), (None, "mlp")),
        "conv_b": Spec((di,), ("mlp",), "zeros"),
        "wq": Spec((di, di), ("mlp", None)),
        "wk": Spec((di, di), ("mlp", None)),
        "wv": Spec((di, di), ("mlp", None)),
        "w_i": Spec((di, h), ("mlp", "heads")),
        "b_i": Spec((h,), ("heads",), "zeros"),
        "w_f": Spec((di, h), ("mlp", "heads")),
        "b_f": Spec((h,), ("heads",), "const", 3.0),  # forget-gate bias high
        "gn": {"scale": Spec((di,), ("mlp",), "ones")},
        "w_down": Spec((di, d), ("mlp", "embed")),
    }


def mlstm_lora_specs(cfg):
    di, r = _inner(cfg), cfg.lora.rank
    out = {}
    for t in cfg.lora.targets:
        if t in ("q", "k", "v"):
            out[f"{t}_a"] = Spec((di, r), ("mlp", "lora_r"))
            out[f"{t}_b"] = Spec((r, di), ("lora_r", None), "zeros")
    return out


def slstm_specs(cfg):
    d, h = cfg.d_model, _heads(cfg)
    hd = d // h
    gates = {}
    for g in ("i", "f", "z", "o"):
        gates[f"w_{g}"] = Spec((d, h, hd), ("embed", "heads", None))
        gates[f"r_{g}"] = Spec((h, hd, hd), ("heads", None, None), "normal", 0.5)
        gates[f"b_{g}"] = Spec((h, hd), ("heads", None),
                               "const" if g == "f" else "zeros",
                               3.0 if g == "f" else 1.0)
    return {
        "ln": {"scale": Spec((d,), ("embed",), "ones"),
               "bias": Spec((d,), ("embed",), "zeros")},
        **gates,
        "gn": {"scale": Spec((d,), ("embed",), "ones")},
        "w_up1": Spec((d, int(d * 4 / 3)), ("embed", "mlp")),
        "w_up2": Spec((d, int(d * 4 / 3)), ("embed", "mlp")),
        "w_down": Spec((int(d * 4 / 3), d), ("mlp", "embed")),
    }


# ---------------------------------------------------------------------------
# mLSTM cell
# ---------------------------------------------------------------------------

def _mlstm_chunk(q, k, v, log_f, log_i, C0, n0, m0):
    """Stabilized chunkwise mLSTM.

    q,k,v: (B,H,L,Dh) fp32; log_f, log_i: (B,H,L);
    carry C0 (B,H,Dh,Dh), n0 (B,H,Dh), m0 (B,H).  Returns h (B,H,L,Dh) + carry.
    """
    B, H, L, Dh = q.shape
    F = jnp.cumsum(log_f, -1)                            # (B,H,L)
    # intra-chunk log weights: D[t,τ] = F[t]-F[τ] + log_i[τ]  (τ<=t)
    Dmat = F[..., :, None] - F[..., None, :] + log_i[..., None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    Dmat = jnp.where(tri, Dmat, NEG_INF)
    # inter-chunk log weight for state from previous chunks: F[t] + m0
    inter = F + m0[..., None]                            # (B,H,L)
    m_t = jnp.maximum(Dmat.max(-1), inter)               # stabilizer per step
    # intra attention-style weights: C[t,τ] = (q_t·k_τ/√d)·exp(D[t,τ]-m_t)
    s = (q @ k.transpose(0, 1, 3, 2)) * (Dh ** -0.5)     # (B,H,L,L)
    w = s * jnp.exp(Dmat - m_t[..., None])
    h_intra = w @ v                                      # (B,H,L,Dh)
    # inter: q · C0 scaled by exp(F[t]+m0-m_t)
    scale_inter = jnp.exp(inter - m_t)[..., None]        # (B,H,L,1)
    h_inter = (q @ C0) * (Dh ** -0.5) * scale_inter
    h_num = h_intra + h_inter
    # normalizer: row-sums of w plus inter normalizer q·n0/√d
    qn0 = jnp.einsum("bhtd,bhd->bht", q, n0) * (Dh ** -0.5)
    row = w.sum(-1) + qn0 * scale_inter[..., 0]          # (B,H,L)
    denom = jnp.maximum(jnp.abs(row), jnp.exp(-m_t))[..., None]
    h = h_num / denom

    # carry update to end of chunk
    m_end = jnp.maximum(F[..., -1] + m0, (F[..., -1:] - F + log_i).max(-1))
    wk = jnp.exp(F[..., -1:] - F + log_i - m_end[..., None])  # (B,H,L)
    C_new = jnp.exp(F[..., -1] + m0 - m_end)[..., None, None] * C0 + \
        jnp.einsum("bhs,bhsd,bhse->bhde", wk, k, v)
    n_new = jnp.exp(F[..., -1] + m0 - m_end)[..., None] * n0 + \
        jnp.einsum("bhs,bhsd->bhd", wk, k)
    return h, C_new, n_new, m_end


def mlstm_cell_step(q, k, v, log_f, log_i, C, n, m):
    """Exact single-step stabilized recurrence.  q,k,v: (B,H,Dh) fp32."""
    Dh = q.shape[-1]
    m_new = jnp.maximum(log_f + m, log_i)                # (B,H)
    fs = jnp.exp(log_f + m - m_new)[..., None, None]
    is_ = jnp.exp(log_i - m_new)[..., None]
    C_new = fs * C + (is_[..., None] * k[..., :, None]) * v[..., None, :]
    n_new = fs[..., 0] * n + is_ * k
    qn = jnp.einsum("bhd,bhd->bh", q, n_new) * (Dh ** -0.5)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))[..., None]
    h = jnp.einsum("bhd,bhde->bhe", q, C_new) * (Dh ** -0.5) / denom
    return h, C_new, n_new, m_new


def _split_heads(x, h):
    B, S, di = x.shape
    return x.reshape(B, S, h, di // h).transpose(0, 2, 1, 3)  # (B,H,S,Dh)


def mlstm_apply(cfg, p, lp, x, *, cache=None):
    """mLSTM block.  x: (B,S,D).  cache: {'conv','C','n','m'} or None."""
    B, S, D = x.shape
    di, H = _inner(cfg), _heads(cfg)
    K = cfg.ssm.conv_kernel
    ls = cfg.lora.alpha / cfg.lora.rank

    xn = apply_norm("layernorm", p["ln"], x)
    xi = xn @ p["w_x"].astype(x.dtype)
    z = xn @ p["w_z"].astype(x.dtype)

    # causal conv on the qk path
    if cache is not None:
        xp = jnp.concatenate([cache["conv"].astype(x.dtype), xi], 1)
    else:
        xp = jnp.pad(xi, ((0, 0), (K - 1, 0), (0, 0)))
    xc = sum(xp[:, i:i + S, :] * p["conv_w"][i].astype(x.dtype) for i in range(K))
    xc = jax.nn.silu(xc + p["conv_b"].astype(x.dtype))
    new_conv = xp[:, -(K - 1):, :]

    def proj(t, src):
        y = src @ p[f"w{t}"].astype(x.dtype)
        if lp is not None and f"{t}_a" in lp:
            y = y + ((src @ lp[f"{t}_a"].astype(x.dtype))
                     @ lp[f"{t}_b"].astype(x.dtype)) * jnp.asarray(ls, x.dtype)
        return y

    q = _split_heads(proj("q", xc), H).astype(jnp.float32)
    k = _split_heads(proj("k", xc), H).astype(jnp.float32)
    v = _split_heads(proj("v", xi), H).astype(jnp.float32)
    log_i = (xc @ p["w_i"].astype(x.dtype) + p["b_i"].astype(x.dtype)
             ).astype(jnp.float32).transpose(0, 2, 1)   # (B,H,S)
    log_f = jax.nn.log_sigmoid(
        (xc @ p["w_f"].astype(x.dtype) + p["b_f"].astype(x.dtype)
         ).astype(jnp.float32)).transpose(0, 2, 1)

    Dh = di // H
    if cache is not None:
        C0 = cache["C"].astype(jnp.float32)
        n0 = cache["n"].astype(jnp.float32)
        m0 = cache["m"].astype(jnp.float32)
    else:
        C0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)
        n0 = jnp.zeros((B, H, Dh), jnp.float32)
        m0 = jnp.zeros((B, H), jnp.float32)

    if S == 1:
        h, C_new, n_new, m_new = mlstm_cell_step(
            q[:, :, 0], k[:, :, 0], v[:, :, 0], log_f[:, :, 0], log_i[:, :, 0],
            C0, n0, m0)
        h = h[:, :, None, :]
    else:
        L = min(cfg.ssm.chunk, S)
        assert S % L == 0
        nc = S // L

        def body(carry, inp):
            C, n, m = carry
            qc, kc, vc, fc, ic = inp
            hh, C2, n2, m2 = _mlstm_chunk(qc, kc, vc, fc, ic, C, n, m)
            return (C2, n2, m2), hh

        xs = (q.reshape(B, H, nc, L, Dh).transpose(2, 0, 1, 3, 4),
              k.reshape(B, H, nc, L, Dh).transpose(2, 0, 1, 3, 4),
              v.reshape(B, H, nc, L, Dh).transpose(2, 0, 1, 3, 4),
              log_f.reshape(B, H, nc, L).transpose(2, 0, 1, 3),
              log_i.reshape(B, H, nc, L).transpose(2, 0, 1, 3))
        (C_new, n_new, m_new), hs = jax.lax.scan(body, (C0, n0, m0), xs)
        h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, Dh)

    h = h.transpose(0, 2, 1, 3).reshape(B, S, di).astype(x.dtype)
    h = apply_norm("rmsnorm", p["gn"], h)                # group-norm stand-in
    out = (h * jax.nn.silu(z)) @ p["w_down"].astype(x.dtype)

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "C": C_new.astype(cache["C"].dtype),
                     "n": n_new.astype(cache["n"].dtype),
                     "m": m_new.astype(cache["m"].dtype)}
    return x + out, new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_apply(cfg, p, lp, x, *, cache=None):
    """sLSTM block.  x: (B,S,D).  cache: {'c','n','m','h'} or None.

    Scalar-memory LSTM with exponential gates, per-head recurrent weights,
    stabilizer state m.  Sequential lax.scan over time.
    """
    B, S, D = x.shape
    H = _heads(cfg)
    hd = D // H
    xn = apply_norm("layernorm", p["ln"], x)

    # pre-compute input contributions for all gates: (B,S,H,hd)
    pre = {g: jnp.einsum("bsd,dhe->bshe", xn, p[f"w_{g}"].astype(x.dtype))
           + p[f"b_{g}"].astype(x.dtype) for g in ("i", "f", "z", "o")}

    if cache is not None:
        c0 = cache["c"].astype(jnp.float32)
        n0 = cache["n"].astype(jnp.float32)
        m0 = cache["m"].astype(jnp.float32)
        h0 = cache["h"].astype(jnp.float32)
    else:
        c0 = jnp.zeros((B, H, hd), jnp.float32)
        n0 = jnp.ones((B, H, hd), jnp.float32)
        m0 = jnp.zeros((B, H, hd), jnp.float32)
        h0 = jnp.zeros((B, H, hd), jnp.float32)

    # recurrent weights stay bf16 (fp32 accumulation): halves the per-step
    # HBM traffic of the sequential scan, which dominates xLSTM's memory
    # roofline term (EXPERIMENTS.md §Perf)
    r = {g: p[f"r_{g}"] for g in ("i", "f", "z", "o")}

    def step(carry, inp):
        c, n, m, h = carry
        pi, pf, pz, po = inp                              # (B,H,hd) each
        rec = {g: jnp.einsum("bhd,hde->bhe", h.astype(r[g].dtype), r[g],
                             preferred_element_type=jnp.float32)
               for g in r}
        log_i = pi.astype(jnp.float32) + rec["i"]
        log_f = jax.nn.log_sigmoid(pf.astype(jnp.float32) + rec["f"])
        zt = jnp.tanh(pz.astype(jnp.float32) + rec["z"])
        ot = jax.nn.sigmoid(po.astype(jnp.float32) + rec["o"])
        m_new = jnp.maximum(log_f + m, log_i)
        ft = jnp.exp(log_f + m - m_new)
        it = jnp.exp(log_i - m_new)
        c_new = ft * c + it * zt
        n_new = ft * n + it
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    xs = tuple(pre[g].transpose(1, 0, 2, 3) for g in ("i", "f", "z", "o"))
    (c_f, n_f, m_f, h_f), hs = jax.lax.scan(step, (c0, n0, m0, h0), xs)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    h = apply_norm("rmsnorm", p["gn"], h)
    x = x + h
    # post-block gated FFN (4/3 factor per xLSTM)
    u = jax.nn.gelu(x @ p["w_up1"].astype(x.dtype)) * (x @ p["w_up2"].astype(x.dtype))
    x = x + u @ p["w_down"].astype(x.dtype)

    new_cache = None
    if cache is not None:
        new_cache = {"c": c_f.astype(cache["c"].dtype),
                     "n": n_f.astype(cache["n"].dtype),
                     "m": m_f.astype(cache["m"].dtype),
                     "h": h_f.astype(cache["h"].dtype)}
    return x, new_cache


def mlstm_cache_specs(cfg, batch: int):
    di, H = _inner(cfg), _heads(cfg)
    Dh = di // H
    K = cfg.ssm.conv_kernel
    return {"conv": Spec((batch, K - 1, di), ("batch", None, "mlp"), "zeros"),
            "C": Spec((batch, H, Dh, Dh), ("batch", "heads", None, None), "zeros"),
            "n": Spec((batch, H, Dh), ("batch", "heads", None), "zeros"),
            "m": Spec((batch, H), ("batch", "heads"), "zeros")}


def slstm_cache_specs(cfg, batch: int):
    D, H = cfg.d_model, _heads(cfg)
    hd = D // H
    sp = {}
    for k_, init in (("c", "zeros"), ("n", "ones"), ("m", "zeros"), ("h", "zeros")):
        sp[k_] = Spec((batch, H, hd), ("batch", "heads", None), init)
    return sp
