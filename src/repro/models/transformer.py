"""Decoder-only LM covering the dense (llama/qwen/olmo) and MoE
(grok / deepseek-v2-with-MLA) families.

Layers are stacked and run under ``lax.scan`` (optionally rematerialized);
MoE models may carry a leading block of dense layers (deepseek's first
layer) which is unrolled in front of the scan.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common, moe as moe_lib, mla as mla_lib
from repro.models.common import (apply_norm, apply_mlp, decoder_block,
                                 block_specs, block_lora_specs, stack_specs)
from repro.models.params import Spec


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def _one_block_specs(cfg, *, use_moe: bool, d_ff: Optional[int] = None):
    p = {"ln1": common.norm_specs(cfg.norm, cfg.d_model),
         "ln2": common.norm_specs(cfg.norm, cfg.d_model)}
    p["attn"] = mla_lib.mla_specs(cfg) if cfg.mla else common.attn_specs(cfg)
    if use_moe:
        p["moe"] = moe_lib.moe_specs(cfg)
    else:
        p["mlp"] = common.mlp_specs(cfg, d_ff)
    return p


def _one_block_lora_specs(cfg):
    return {"attn": (mla_lib.mla_lora_specs(cfg) if cfg.mla
                     else common.attn_lora_specs(cfg))}


def _n_prefix(cfg) -> int:
    return cfg.moe.first_dense_layers if cfg.moe else 0


def lm_specs(cfg):
    n_prefix = _n_prefix(cfg)
    n_scan = cfg.num_layers - n_prefix
    frozen = {
        "embed": Spec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), "embed"),
        "blocks": stack_specs(n_scan, _one_block_specs(
            cfg, use_moe=cfg.moe is not None)),
        "final_norm": common.norm_specs(cfg.norm, cfg.d_model),
    }
    if n_prefix:
        frozen["prefix"] = [
            _one_block_specs(cfg, use_moe=False, d_ff=cfg.moe.dense_d_ff)
            for _ in range(n_prefix)]
    if not cfg.tie_embeddings:
        frozen["head"] = Spec((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"))
    lora = {"blocks": stack_specs(n_scan, _one_block_lora_specs(cfg))}
    if n_prefix:
        lora["prefix"] = [_one_block_lora_specs(cfg) for _ in range(n_prefix)]
    return {"frozen": frozen, "lora": lora}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block_apply(cfg, p, lp, x, *, positions, cache=None, window=0,
                 chunk=2048, use_moe=False):
    aux = jnp.zeros((), jnp.float32)
    xn = apply_norm(cfg.norm, p["ln1"], x)
    if cfg.mla:
        if cache is not None:
            h, new_cache = mla_lib.mla_decode(cfg, p["attn"],
                                              lp["attn"] if lp else None, xn, cache)
        else:
            h = mla_lib.mla_full(cfg, p["attn"], lp["attn"] if lp else None,
                                 xn, positions=positions, chunk=chunk)
            new_cache = None
    else:
        h, new_cache = common.attn_apply(
            cfg, p["attn"], lp["attn"] if lp else None, xn,
            positions=positions, cache=cache, window=window, chunk=chunk)
    x = x + h
    xn = apply_norm(cfg.norm, p["ln2"], x)
    if use_moe:
        f, a = moe_lib.moe_apply(cfg, p["moe"], xn)
        aux = aux + a
    else:
        f = apply_mlp(cfg, p["mlp"], xn)
    return x + f, new_cache, aux


def run_block_range(cfg, frozen, lora, x, lo: int, hi: int, *,
                    positions=None, window=0, chunk=2048, remat=False):
    """Scan decoder blocks ``[lo, hi)`` of the stacked (non-prefix,
    non-MoE) layer block — the causal-LM split-learning building block
    shared by :class:`repro.models.split_api.CausalLMSplitModel` and
    usable standalone.  Returns the transformed activations."""
    if lo == hi:
        return x
    if positions is None:
        positions = jnp.arange(x.shape[1])

    def body(xc, pl):
        p, lp = pl
        y, _, _ = _block_apply(cfg, p, lp, xc, positions=positions,
                               window=window, chunk=chunk, use_moe=False)
        return y, None

    if remat:
        body = jax.checkpoint(body)
    sl = jax.tree_util.tree_map(lambda a: a[lo:hi], frozen["blocks"])
    ll = (jax.tree_util.tree_map(lambda a: a[lo:hi], lora["blocks"])
          if lora else None)
    return jax.lax.scan(body, x, (sl, ll))[0]


def lm_forward(cfg, params, lora, tokens, *, window=0, chunk=2048,
               remat=True, boundaries=None, channel=None):
    """tokens: (B, S) -> logits (B, S, padded_vocab), aux loss.

    ``boundaries=(b1, b2)`` + ``channel`` enable ELSA's tripartite split:
    the layer scan is cut at blocks b1 and b1+b2 (Part 1 / Part 2 / Part 3)
    and activations crossing each cut pass through ``channel``
    (SS-OP ∘ sketch ∘ decode ∘ SS-OPᵀ) — exactly §III.B.2-3 mapped onto
    the pod (DESIGN.md §3).
    """
    frozen = params
    B, S = tokens.shape
    x = jnp.take(frozen["embed"], tokens, axis=0).astype(cfg.adtype())
    positions = jnp.arange(S)
    aux_total = jnp.zeros((), jnp.float32)

    for i in range(_n_prefix(cfg)):
        x, _, aux = _block_apply(
            cfg, frozen["prefix"][i], lora["prefix"][i] if lora else None, x,
            positions=positions, window=window, chunk=chunk, use_moe=False)
        aux_total += aux

    use_moe = cfg.moe is not None

    def body(carry, pl):
        xc, aux_acc = carry
        p, lp = pl
        y, _, aux = _block_apply(cfg, p, lp, xc, positions=positions,
                                 window=window, chunk=chunk, use_moe=use_moe)
        return (y, aux_acc + aux), None

    if remat:
        body = jax.checkpoint(body)

    def seg_scan(carry, lo, hi):
        sl = jax.tree_util.tree_map(lambda a: a[lo:hi], frozen["blocks"])
        ll = (jax.tree_util.tree_map(lambda a: a[lo:hi], lora["blocks"])
              if lora else None)
        return jax.lax.scan(body, carry, (sl, ll))[0]

    n_scan = cfg.num_layers - _n_prefix(cfg)
    if boundaries and channel is not None:
        b1, b2 = boundaries
        (x, aux_total) = seg_scan((x, aux_total), 0, b1)
        x = channel(x)                           # client -> edge cut
        (x, aux_total) = seg_scan((x, aux_total), b1, b1 + b2)
        x = channel(x)                           # edge -> client cut
        (x, aux_total) = seg_scan((x, aux_total), b1 + b2, n_scan)
    else:
        (x, aux_total), _ = jax.lax.scan(
            body, (x, aux_total),
            (frozen["blocks"], lora["blocks"] if lora else None))

    x = apply_norm(cfg.norm, frozen["final_norm"], x)
    head = frozen.get("head", None)
    if head is None:
        logits = x @ frozen["embed"].T.astype(x.dtype)
    else:
        logits = x @ head.astype(x.dtype)
    return logits, aux_total


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def lm_cache_specs(cfg, batch: int, seq_len: int):
    n_prefix = _n_prefix(cfg)
    n_scan = cfg.num_layers - n_prefix
    if cfg.mla:
        a = cfg.mla
        one = {"c_kv": Spec((batch, seq_len, a.kv_lora_rank), ("batch", None, None)),
               "k_rope": Spec((batch, seq_len, a.rope_head_dim), ("batch", None, None)),
               "len": Spec((), (), "zeros", 1.0, "int32")}
    else:
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        window = cfg.sliding_window
        ring = bool(window) and seq_len > window
        s_cache = window if ring else seq_len
        one = {"k": Spec((batch, s_cache, kv, hd), ("batch", None, "kv_heads", None)),
               "v": Spec((batch, s_cache, kv, hd), ("batch", None, "kv_heads", None)),
               "len": Spec((), (), "zeros", 1.0, "int32")}
        if ring:
            one["pos"] = Spec((s_cache,), (None,), "const", -1e9, "int32")
    caches = {"blocks": stack_specs(n_scan, one)}
    if n_prefix:
        caches["prefix"] = [one for _ in range(n_prefix)]
    return caches


def lm_decode_step(cfg, params, lora, cache, tokens, *, window=0, chunk=4096):
    """tokens: (B, 1); cache from lm_cache_specs -> (logits, new_cache)."""
    frozen = params
    x = jnp.take(frozen["embed"], tokens, axis=0).astype(cfg.adtype())
    use_moe = cfg.moe is not None
    new_prefix = []
    for i in range(_n_prefix(cfg)):
        c = cache["prefix"][i]
        pos = c["len"] + jnp.arange(1)
        x, nc, _ = _block_apply(cfg, frozen["prefix"][i],
                                lora["prefix"][i] if lora else None, x,
                                positions=pos, cache=c, window=window,
                                chunk=chunk, use_moe=False)
        new_prefix.append(nc)

    def body(xc, pl):
        p, lp, c = pl
        pos = c["len"] + jnp.arange(1)
        y, nc, _ = _block_apply(cfg, p, lp, xc, positions=pos, cache=c,
                                window=window, chunk=chunk, use_moe=use_moe)
        return y, nc

    x, new_blocks = jax.lax.scan(
        body, x, (frozen["blocks"], lora["blocks"] if lora else None,
                  cache["blocks"]))
    x = apply_norm(cfg.norm, frozen["final_norm"], x)
    head = frozen.get("head", None)
    logits = (x @ frozen["embed"].T.astype(x.dtype) if head is None
              else x @ head.astype(x.dtype))
    new_cache = {"blocks": new_blocks}
    if new_prefix:
        new_cache["prefix"] = new_prefix
    return logits, new_cache
