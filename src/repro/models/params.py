"""Lightweight parameter-spec system (no flax).

A model declares its parameters as a pytree of :class:`Spec` leaves; the
framework can then materialize real arrays (smoke tests / real training),
abstract ``ShapeDtypeStruct`` trees (multi-pod dry-run), or
``NamedSharding`` trees (pjit in_shardings) from the same declaration —
guaranteeing the three never drift apart.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


class Spec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names, len == len(shape)
    init: str = "normal"              # normal | zeros | ones | const | embed
    scale: float = 1.0                # stddev multiplier / const value
    dtype: Optional[str] = None       # per-leaf dtype override (e.g. 'int32')

    def fan_in_scale(self) -> float:
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        return 1.0 / math.sqrt(max(fan_in, 1))


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _leaf_init(spec: Spec, key, dtype):
    if spec.dtype is not None:
        dtype = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "const":
        return jnp.full(spec.shape, spec.scale, dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape, jnp.float32) * 0.02
                ).astype(dtype)
    # 'normal': truncated-normal-ish fan-in scaled
    std = spec.scale * spec.fan_in_scale()
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)


def init_tree(specs, key, dtype=jnp.float32):
    """Materialize real parameters from a spec tree."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_leaf_init(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_tree(specs, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins — no allocation; used by the dry-run."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.dtype(s.dtype) if s.dtype else dtype),
        specs, is_leaf=is_spec)


# Logical-axis -> mesh-axis rules.  A mesh axis is applied to a dim only when
# the dim size is divisible by the mesh axis size (whisper's 12 heads on a
# 16-way model axis fall back to replication); each mesh axis is used at most
# once per tensor.
DEFAULT_RULES = {
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": (),            # tensor-parallel inside experts by default
    "embed": (),
    "layers": (),
    "lora_r": (),
    "state": (),
    # 'batch' maps to the (composite) data-parallel axes; see partition_spec
    "batch": (("pod", "data"), ("data",)),
}


def partition_spec(spec: Spec, mesh: Mesh, rules=None) -> PartitionSpec:
    rules = rules or DEFAULT_RULES
    used = set()
    out = []
    for dim, logical in zip(spec.shape, spec.axes):
        assigned = None
        for cand in rules.get(logical, ()) if logical else ():
            axes = cand if isinstance(cand, tuple) else (cand,)
            if any(a in used or a not in mesh.shape for a in axes):
                continue
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if dim % size == 0:
                assigned = cand
                used.update(axes)
                break
        out.append(assigned)
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def tree_shardings(specs, mesh: Mesh, rules=None):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, partition_spec(s, mesh, rules)),
        specs, is_leaf=is_spec)


def tree_pspecs(specs, mesh: Mesh, rules=None):
    return jax.tree_util.tree_map(
        lambda s: partition_spec(s, mesh, rules), specs, is_leaf=is_spec)


def count_params(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))
