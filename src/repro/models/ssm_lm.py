"""xLSTM language model [arXiv:2405.04517]: 48 blocks in 6 periods of
(7 mLSTM + 1 sLSTM), scanned over periods.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common, xlstm as xl
from repro.models.common import apply_norm, stack_specs
from repro.models.params import Spec


def _period(cfg) -> int:
    return cfg.ssm.slstm_every


def _n_periods(cfg) -> int:
    assert cfg.num_layers % _period(cfg) == 0
    return cfg.num_layers // _period(cfg)


def _period_kinds(cfg):
    per = _period(cfg)
    return ["slstm" if i == per - 1 else "mlstm" for i in range(per)]


def xlstm_specs(cfg):
    kinds = _period_kinds(cfg)
    period_p = {f"l{i}": (xl.slstm_specs(cfg) if k == "slstm"
                          else xl.mlstm_specs(cfg))
                for i, k in enumerate(kinds)}
    period_l = {f"l{i}": ({} if k == "slstm" else xl.mlstm_lora_specs(cfg))
                for i, k in enumerate(kinds)}
    frozen = {
        "embed": Spec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), "embed"),
        "periods": stack_specs(_n_periods(cfg), period_p),
        "final_norm": common.norm_specs("layernorm", cfg.d_model),
        "head": Spec((cfg.d_model, cfg.padded_vocab), ("embed", "vocab")),
    }
    return {"frozen": frozen,
            "lora": {"periods": stack_specs(_n_periods(cfg), period_l)}}


def xlstm_forward(cfg, params, lora, tokens, *, remat=True, **_):
    kinds = _period_kinds(cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype())

    def body(xc, pl):
        p, lp = pl
        for i, kind in enumerate(kinds):
            if kind == "slstm":
                xc, _ = xl.slstm_apply(cfg, p[f"l{i}"], None, xc)
            else:
                xc, _ = xl.mlstm_apply(cfg, p[f"l{i}"],
                                       lp[f"l{i}"] if lp else None, xc)
        return xc, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, (params["periods"],
                                  lora["periods"] if lora else None))
    x = apply_norm("layernorm", params["final_norm"], x)
    return x @ params["head"].astype(x.dtype), jnp.zeros((), jnp.float32)


def xlstm_cache_specs(cfg, batch: int, seq_len: int):
    kinds = _period_kinds(cfg)
    per = {f"l{i}": (xl.slstm_cache_specs(cfg, batch) if k == "slstm"
                     else xl.mlstm_cache_specs(cfg, batch))
           for i, k in enumerate(kinds)}
    return {"periods": stack_specs(_n_periods(cfg), per)}


def xlstm_decode_step(cfg, params, lora, cache, tokens, **_):
    kinds = _period_kinds(cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype())

    def body(xc, pl):
        p, lp, c = pl
        ncs = {}
        for i, kind in enumerate(kinds):
            if kind == "slstm":
                xc, nc = xl.slstm_apply(cfg, p[f"l{i}"], None, xc,
                                        cache=c[f"l{i}"])
            else:
                xc, nc = xl.mlstm_apply(cfg, p[f"l{i}"],
                                        lp[f"l{i}"] if lp else None, xc,
                                        cache=c[f"l{i}"])
            ncs[f"l{i}"] = nc
        return xc, ncs

    x, new_periods = jax.lax.scan(
        body, x, (params["periods"], lora["periods"] if lora else None,
                  cache["periods"]))
    x = apply_norm("layernorm", params["final_norm"], x)
    return x @ params["head"].astype(x.dtype), {"periods": new_periods}
