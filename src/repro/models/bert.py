"""BERT-base encoder — the paper's own model (§IV.A).

Post-LN encoder with token/position/segment embeddings, [CLS] pooler, and a
pluggable classification head.  Exposes both sequence representations (for
ELSA's behavioral fingerprints, Eq. 4) and per-layer split execution (for
the tripartite split training, §III.B.2): ``run_blocks(lo, hi)`` runs
blocks [lo, hi) so Part 1 / Part 2 / Part 3 of the split are literal slices
of the same parameter tree.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import apply_norm, apply_mlp, attn_apply, stack_specs
from repro.models.params import Spec


def bert_specs(cfg, num_classes: int = 2):
    d = cfg.d_model
    block = {"attn": common.attn_specs(cfg),
             "ln1": common.norm_specs("layernorm", d),
             "mlp": common.mlp_specs(cfg),
             "ln2": common.norm_specs("layernorm", d)}
    frozen = {
        "embed": Spec((cfg.padded_vocab, d), ("vocab", "embed"), "embed"),
        "pos": Spec((cfg.max_position_embeddings, d), (None, "embed"), "embed"),
        "seg": Spec((2, d), (None, "embed"), "embed"),
        "ln_embed": common.norm_specs("layernorm", d),
        "blocks": stack_specs(cfg.num_layers, block),
    }
    lora = {"blocks": stack_specs(cfg.num_layers,
                                  {"attn": common.attn_lora_specs(cfg)})}
    # task head is trainable (paper: output layer trainable, negligible size)
    lora["pooler"] = {"w": Spec((d, d), ("embed", None)),
                      "b": Spec((d,), (None,), "zeros")}
    lora["head"] = {"w": Spec((d, num_classes), ("embed", None)),
                    "b": Spec((num_classes,), (None,), "zeros")}
    return {"frozen": frozen, "lora": lora}


def embed(cfg, params, tokens, segments=None):
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + params["pos"][:S][None]
    if segments is not None:
        x = x + jnp.take(params["seg"], segments, axis=0)
    return apply_norm("layernorm", params["ln_embed"], x.astype(cfg.adtype()))


def block_apply(cfg, p, lp, x, *, mask_valid: Optional[jnp.ndarray] = None):
    """Post-LN BERT block.  mask_valid: (B, S) bool attention mask."""
    positions = jnp.arange(x.shape[1])
    h, _ = attn_apply(cfg, p["attn"], lp["attn"] if lp else None, x,
                      positions=positions, causal=False)
    x = apply_norm("layernorm", p["ln1"], x + h)
    f = apply_mlp(cfg, p["mlp"], x)
    x = apply_norm("layernorm", p["ln2"], x + f)
    if mask_valid is not None:
        x = x * mask_valid[..., None].astype(x.dtype)
    return x


def run_blocks(cfg, params, lora, x, lo: int, hi: int,
               mask_valid: Optional[jnp.ndarray] = None):
    """Run encoder blocks [lo, hi) — the split-learning building block.

    Uses a python loop over layer slices (p_n/q_n/o are small and dynamic
    per client; the federation simulation runs reduced models).
    """
    for i in range(lo, hi):
        p = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
        lp = (jax.tree_util.tree_map(lambda a: a[i], lora["blocks"])
              if lora else None)
        x = block_apply(cfg, p, lp, x, mask_valid=mask_valid)
    return x


def bert_forward(cfg, params, lora, tokens, segments=None, mask_valid=None,
                 **_):
    """Full encoder -> (sequence_output, cls_embedding, logits)."""
    frozen = params
    x = embed(cfg, frozen, tokens, segments)
    x = run_blocks(cfg, frozen, lora, x, 0, cfg.num_layers, mask_valid)
    cls = x[:, 0, :]
    logits = None
    if lora is not None and "head" in lora:
        pooled = jnp.tanh(cls @ lora["pooler"]["w"].astype(cls.dtype)
                          + lora["pooler"]["b"].astype(cls.dtype))
        logits = pooled @ lora["head"]["w"].astype(cls.dtype) \
            + lora["head"]["b"].astype(cls.dtype)
    return x, cls, logits
