"""Shared model primitives: norms, rotary, GQA attention (direct + chunked
online-softmax + decode), MLPs, LoRA application, spec builders.

All functions are pure; parameters are plain pytrees built from
``repro.models.params.Spec`` trees.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.params import Spec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# spec helpers
# ---------------------------------------------------------------------------

def stack_specs(n: int, tree):
    """Prepend a ('layers', n) dim to every Spec in the tree (for lax.scan)."""
    return jax.tree_util.tree_map(
        lambda s: Spec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale,
                       s.dtype),
        tree, is_leaf=lambda x: isinstance(x, Spec))


def norm_specs(kind: str, d: int):
    if kind == "rmsnorm":
        return {"scale": Spec((d,), ("embed",), "ones")}
    if kind == "layernorm":
        return {"scale": Spec((d,), ("embed",), "ones"),
                "bias": Spec((d,), ("embed",), "zeros")}
    if kind == "nonparametric":
        return {}
    raise ValueError(kind)


def attn_specs(cfg, *, cross: bool = False):
    """q/k/v/o projection specs (+ optional bias, + LoRA adapters)."""
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    p = {
        "wq": Spec((d, h, hd), ("embed", "heads", None)),
        "wk": Spec((d, kv, hd), ("embed", "kv_heads", None)),
        "wv": Spec((d, kv, hd), ("embed", "kv_heads", None)),
        "wo": Spec((h, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = Spec((h, hd), ("heads", None), "zeros")
        p["bk"] = Spec((kv, hd), ("kv_heads", None), "zeros")
        p["bv"] = Spec((kv, hd), ("kv_heads", None), "zeros")
    return p


def attn_lora_specs(cfg):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    r = cfg.lora.rank
    out = {}
    dims = {"q": (h, hd), "k": (kv, hd), "v": (kv, hd), "o": (d,)}
    for t in cfg.lora.targets:
        if t not in dims:
            continue
        if t == "o":
            out[f"{t}_a"] = Spec((h, hd, r), ("heads", None, "lora_r"))
            out[f"{t}_b"] = Spec((r, d), ("lora_r", "embed"), "zeros")
        else:
            n, e = dims[t]
            out[f"{t}_a"] = Spec((d, r), ("embed", "lora_r"))
            out[f"{t}_b"] = Spec((r, n, e), ("lora_r", "kv_heads" if t in ("k", "v") else "heads", None), "zeros")
    return out


def mlp_specs(cfg, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.norm == "layernorm":   # classic (whisper/bert/xlstm): 2-matrix MLP
        return {"w_in": Spec((d, f), ("embed", "mlp")),
                "b_in": Spec((f,), ("mlp",), "zeros"),
                "w_out": Spec((f, d), ("mlp", "embed")),
                "b_out": Spec((d,), ("embed",), "zeros")}
    return {"w_gate": Spec((d, f), ("embed", "mlp")),
            "w_up": Spec((d, f), ("embed", "mlp")),
            "w_down": Spec((f, d), ("mlp", "embed"))}


# ---------------------------------------------------------------------------
# norms / activations / rotary
# ---------------------------------------------------------------------------

def _acc_dtype(x):
    """Accumulation dtype: at least f32, but keep f64 inputs in f64 so
    x64-mode parity runs are not silently re-quantized to f32."""
    return jnp.promote_types(x.dtype, jnp.float32)


def apply_norm(kind: str, p, x, eps: float = 1e-5):
    xf = x.astype(_acc_dtype(x))
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (y * p["scale"].astype(xf.dtype)).astype(x.dtype)
    mean = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), -1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * p["scale"].astype(xf.dtype) + p["bias"].astype(xf.dtype)
    return y.astype(x.dtype)


def activation(kind: str, x):
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def rope(x, positions, theta: float):
    """x: (..., S, n, head_dim); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs      # (...,S,half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# LoRA
# ---------------------------------------------------------------------------

def lora_delta(lp, target: str, x, scale: float):
    """x (..., D) -> adapter output reshaped like the target projection."""
    a, b = lp.get(f"{target}_a"), lp.get(f"{target}_b")
    if a is None:
        return None
    t = x @ a.reshape(-1, a.shape[-1]).astype(x.dtype) if a.ndim > 2 else x @ a.astype(x.dtype)
    out = jnp.tensordot(t, b.astype(x.dtype), axes=1)
    return out * jnp.asarray(scale, x.dtype)


def project(p, lp, x, target: str, lora_scale: float):
    """Fused frozen projection + LoRA adapter for q/k/v."""
    w = p[f"w{target}"]
    y = jnp.einsum("...d,dne->...ne", x, w.astype(x.dtype))
    if f"b{target}" in p:
        y = y + p[f"b{target}"].astype(x.dtype)
    if lp is not None:
        d = lora_delta(lp, target, x, lora_scale)
        if d is not None:
            y = y + d
    return y


def out_project(p, lp, att, x_shape_dtype, lora_scale: float):
    y = jnp.einsum("...ne,ned->...d", att, p["wo"].astype(att.dtype))
    if lp is not None and "o_a" in lp:
        t = jnp.einsum("...ne,ner->...r", att, lp["o_a"].astype(att.dtype))
        y = y + (t @ lp["o_b"].astype(att.dtype)) * jnp.asarray(lora_scale, att.dtype)
    return y


# ---------------------------------------------------------------------------
# attention core
# ---------------------------------------------------------------------------

def _mask(q_pos, k_pos, *, causal: bool, window: int, kv_valid: Optional[jnp.ndarray]):
    """q_pos (Sq,), k_pos (Sk,) -> bool (Sq, Sk), True = attend."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    if kv_valid is not None:
        m &= k_pos[None, :] < kv_valid
    return m


def _chunked_attn_fwd_core(qr, ks, vs, kpos_chunks, q_pos, *, causal,
                           window, kv_valid, scale):
    """Online-softmax forward over kv chunks.

    qr: (B,Sq,KV,G,Dh); ks/vs: (nc, B, C, KV, Dh); returns (o, m, l) with
    o (B,KV,G,Sq,Dv) fp32, m/l (B,KV,G,Sq) fp32.
    """
    B, Sq, KV, G, Dh = qr.shape
    Dv = vs.shape[-1]
    acc_dt = _acc_dtype(qr)

    def body(carry, inp):
        m_run, l_run, acc = carry
        kc, vc, k_pos = inp
        s = jnp.einsum("bqkgd,bskd->bkgqs", qr, kc,
                       preferred_element_type=acc_dt) * scale
        msk = _mask(q_pos, k_pos, causal=causal, window=window,
                    kv_valid=kv_valid)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(vc.dtype), vc).astype(acc_dt)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, acc_dt)
    l0 = jnp.zeros((B, KV, G, Sq), acc_dt)
    a0 = jnp.zeros((B, KV, G, Sq, Dv), acc_dt)
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      (ks, vs, kpos_chunks))
    o = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return o, m_f, l_f


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _chunked_attn(qr, ks, vs, causal, window, scale, chunk):
    """Flash-style chunked attention with memory-lean backward.

    The naive differentiation of the online-softmax scan saves the fp32
    (Sq x chunk) probability block for EVERY chunk step — the full S×S
    attention matrix.  This custom VJP saves only (q, k, v, o, m, l) and
    re-materializes probability blocks one chunk at a time in backward
    (the standard flash-attention backward).
    """
    nc = ks.shape[0]
    q_pos = jnp.arange(qr.shape[1])
    kpos = jnp.arange(nc * chunk).reshape(nc, chunk)
    o, _, _ = _chunked_attn_fwd_core(qr, ks, vs, kpos, q_pos, causal=causal,
                                     window=window, kv_valid=None,
                                     scale=scale)
    return o


def _chunked_attn_fwd(qr, ks, vs, causal, window, scale, chunk):
    nc = ks.shape[0]
    q_pos = jnp.arange(qr.shape[1])
    kpos = jnp.arange(nc * chunk).reshape(nc, chunk)
    o, m, l = _chunked_attn_fwd_core(qr, ks, vs, kpos, q_pos, causal=causal,
                                     window=window, kv_valid=None,
                                     scale=scale)
    return o, (qr, ks, vs, o, m, l)


def _chunked_attn_bwd(causal, window, scale, chunk, res, do):
    qr, ks, vs, o, m, l = res
    B, Sq, KV, G, Dh = qr.shape
    nc = ks.shape[0]
    acc_dt = _acc_dtype(qr)
    q_pos = jnp.arange(Sq)
    l_safe = jnp.maximum(l, 1e-30)
    # D_i = sum_d do_i * o_i  (B,KV,G,Sq)
    dsum = jnp.einsum("bkgqd,bkgqd->bkgq", do.astype(acc_dt), o)

    def body(dq_acc, inp):
        kc, vc, k_pos = inp
        s = jnp.einsum("bqkgd,bskd->bkgqs", qr, kc,
                       preferred_element_type=acc_dt) * scale
        msk = _mask(q_pos, k_pos, causal=causal, window=window,
                    kv_valid=None)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        p = jnp.exp(s - m[..., None]) / l_safe[..., None]      # normalized
        dp = jnp.einsum("bkgqd,bskd->bkgqs", do.astype(acc_dt),
                        vc.astype(acc_dt))
        ds = p * (dp - dsum[..., None]) * scale
        dv_c = jnp.einsum("bkgqs,bkgqd->bskd", p,
                          do.astype(acc_dt)).astype(vs.dtype)
        dk_c = jnp.einsum("bkgqs,bqkgd->bskd", ds.astype(qr.dtype),
                          qr).astype(ks.dtype)
        dq_acc = dq_acc + jnp.einsum("bkgqs,bskd->bqkgd",
                                     ds.astype(kc.dtype), kc)
        return dq_acc, (dk_c, dv_c)

    kpos = jnp.arange(nc * chunk).reshape(nc, chunk)
    dq0 = jnp.zeros(qr.shape, qr.dtype)
    dq, (dk, dv) = jax.lax.scan(body, dq0, (ks, vs, kpos))
    return dq, dk, dv


_chunked_attn.defvjp(_chunked_attn_fwd, _chunked_attn_bwd)


def gqa_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                  kv_offset=0, kv_valid=None, chunk=2048, use_flash=False,
                  scale=None, k_positions=None):
    """Grouped-query attention with online-softmax kv chunking.

    q: (B, Sq, H, Dh); k, v: (B, Sk, KV, Dh).  ``q_offset`` is the absolute
    position of q[:,0]; ``kv_valid`` masks cache slots >= current length.
    Never materializes an (Sq, Sk) tensor when Sk > chunk.
    """
    if use_flash:
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            kv_valid=kv_valid)
    B, Sq, H, Dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = Dh ** -0.5 if scale is None else scale
    qr = q.reshape(B, Sq, KV, G, Dh)
    q_pos = q_offset + jnp.arange(Sq)

    if Sk <= chunk:
        k_pos = k_positions if k_positions is not None else kv_offset + jnp.arange(Sk)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qr, k,
                       preferred_element_type=_acc_dtype(q)) * scale
        m = _mask(q_pos, k_pos, causal=causal, window=window, kv_valid=kv_valid)
        s = jnp.where(m[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
        return o.reshape(B, Sq, H, v.shape[-1])

    # chunked online softmax over kv
    n_chunks = Sk // chunk
    assert Sk % chunk == 0, f"Sk={Sk} not divisible by chunk={chunk}"
    ks = k.reshape(B, n_chunks, chunk, KV, k.shape[-1]).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, n_chunks, chunk, KV, v.shape[-1]).transpose(1, 0, 2, 3, 4)
    Dv = v.shape[-1]

    standard = (kv_valid is None and k_positions is None
                and isinstance(q_offset, int) and q_offset == 0
                and isinstance(kv_offset, int) and kv_offset == 0)
    if standard:
        # train/prefill: flash-style custom VJP (memory-lean backward)
        o = _chunked_attn(qr, ks, vs, causal, window, scale, chunk)
        return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv).astype(q.dtype)

    # decode path (dynamic offsets / ring positions); no grad flows here
    if k_positions is not None:
        kpos_chunks = k_positions.reshape(n_chunks, chunk)
    else:
        kpos_chunks = (kv_offset + jnp.arange(Sk)).reshape(n_chunks, chunk)
    o, _, _ = _chunked_attn_fwd_core(
        qr, ks, vs, kpos_chunks, q_pos, causal=causal, window=window,
        kv_valid=kv_valid, scale=scale)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv).astype(q.dtype)


def apply_mlp(cfg, p, x, d_ff: Optional[int] = None):
    if "w_in" in p:
        h = activation(cfg.act, x @ p["w_in"].astype(x.dtype) + p["b_in"].astype(x.dtype))
        return h @ p["w_out"].astype(x.dtype) + p["b_out"].astype(x.dtype)
    g = activation(cfg.act, x @ p["w_gate"].astype(x.dtype))
    return (g * (x @ p["w_up"].astype(x.dtype))) @ p["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# standard decoder block (dense archs; also used by vlm/hybrid attn layers)
# ---------------------------------------------------------------------------

def block_specs(cfg, d_ff: Optional[int] = None):
    return {
        "ln1": norm_specs(cfg.norm, cfg.d_model),
        "attn": attn_specs(cfg),
        "ln2": norm_specs(cfg.norm, cfg.d_model),
        "mlp": mlp_specs(cfg, d_ff),
    }


def block_lora_specs(cfg):
    return {"attn": attn_lora_specs(cfg)}


def attn_apply(cfg, p, lp, x, *, positions, cache=None, window=0,
               causal=True, chunk=2048):
    """Self-attention sublayer.  With ``cache`` (decode): k/v appended at
    ``positions`` and attention runs over the cache."""
    ls = cfg.lora.alpha / cfg.lora.rank
    q = project(p, lp, x, "q", ls)
    k = project(p, lp, x, "k", ls)
    v = project(p, lp, x, "v", ls)
    if cfg.max_position_embeddings == 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if cache is not None:
        ck, cv, cur = cache["k"], cache["v"], cache["len"]
        ring = "pos" in cache          # windowed ring-buffer cache
        idx = cur % ck.shape[1] if ring else cur
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), idx, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), idx, 1)
        if ring:
            pos = jax.lax.dynamic_update_slice_in_dim(
                cache["pos"], cur + jnp.arange(q.shape[1], dtype=jnp.int32),
                idx, 0)
            o = gqa_attention(q, ck, cv, causal=True, window=window,
                              q_offset=cur, k_positions=pos, chunk=chunk)
            new_cache = {"k": ck, "v": cv, "pos": pos, "len": cur + q.shape[1]}
        else:
            o = gqa_attention(q, ck, cv, causal=True, window=window,
                              q_offset=cur, kv_valid=cur + q.shape[1],
                              chunk=chunk)
            new_cache = {"k": ck, "v": cv, "len": cur + q.shape[1]}
        return out_project(p, lp, o, x, ls), new_cache
    # train/prefill: positions start at 0 (static), keeping the
    # flash-style custom-VJP path eligible
    o = gqa_attention(q, k, v, causal=causal, window=window, q_offset=0,
                      chunk=chunk)
    return out_project(p, lp, o, x, ls), None


def decoder_block(cfg, p, lp, x, *, positions, cache=None, window=0,
                  chunk=2048):
    h, new_cache = attn_apply(cfg, p["attn"],
                              lp["attn"] if lp else None, apply_norm(cfg.norm, p["ln1"], x),
                              positions=positions, cache=cache, window=window,
                              chunk=chunk)
    x = x + h
    x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg.norm, p["ln2"], x))
    return x, new_cache
