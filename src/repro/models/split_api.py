"""Model-agnostic split-federation API: the ``SplitModel`` protocol.

ELSA's splitting, sketching, and aggregation (§III.B.2, Eqs. 7–9) are
defined over an abstract M-block model: an embedding, a stack of blocks
cut at ``(p, p+q)``, and a task head.  This module pins that contract
down as a small frozen interface so every split-federation consumer —
:mod:`repro.core.split_training`, the batched engine, the communication
and wall-clock cost models, and the :class:`~repro.federation.simulation.
Federation` harness — dispatches on the protocol instead of importing
``repro.models.bert`` directly.

The protocol (one adapter instance per :class:`~repro.configs.base.
ArchConfig`, stateless and hashable-by-config):

- ``specs(num_classes)`` / ``lora_specs(num_classes)`` — parameter Spec
  trees (``{"frozen": ..., "lora": ...}``);
- ``embed(frozen, tokens)`` — token ids -> block-stack activations;
- ``run_blocks(frozen, lora, x, lo, hi)`` — run blocks ``[lo, hi)`` so
  Part 1 / Part 2 / Part 3 of the tripartite split are literal slices;
- ``head(frozen, lora, x)`` -> ``(repr, logits)`` — the task readout
  plus the pooled representation used for behavioral fingerprints
  (Eq. 4) and SS-OP basis construction;
- ``per_example_loss(logits, batch)`` -> ``(B,)`` — per-example so the
  engine's zero-weight padding rows cancel exactly;
- ``accuracy(logits, tokens, labels)`` — host-side eval metric;
- ``num_blocks`` / ``activation_shape`` / ``block_param_count`` /
  ``head_param_count`` / ``flops_per_token`` — the shape and 6ND cost
  facts the Eq. 22–24 communication model and the runtime cost model
  derive their constants from.

Adapters: :class:`BertSplitModel` (the paper's encoder, classification
readout at [CLS]) and :class:`CausalLMSplitModel` (any dense decoder-only
LM from the zoo — llama/qwen/olmo-style — with a next-token-CE task).
``get_split_model(name)`` resolves registered architecture names;
``split_model_for(cfg)`` adapts an existing ``ArchConfig`` by family.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Callable, Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY as ARCH_REGISTRY, get_config
from repro.configs.base import ArchConfig
from repro.models import bert as bert_mod
from repro.models import transformer
from repro.models.common import apply_norm
from repro.models.params import is_spec
from repro.models.zoo import per_example_ce


def _spec_params(tree) -> float:
    return float(sum(np.prod(s.shape) for s in
                     jax.tree_util.tree_leaves(tree, is_leaf=is_spec)))


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------

class SplitModel:
    """Abstract M-block model the split-federation machinery runs on.

    Subclasses adapt one architecture family; instances are stateless
    wrappers around an :class:`ArchConfig` (parameters are always passed
    in, never held), so one adapter can be closed over by jitted
    functions and shared across a federation.
    """

    #: "classification" (labels readout) or "causal-lm" (next-token CE)
    task: str = "classification"

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    @property
    def name(self) -> str:
        return self.cfg.name

    @property
    def num_blocks(self) -> int:
        """Number of splittable blocks (Eq. 7's M)."""
        return self.cfg.num_layers

    # -- parameters ---------------------------------------------------------
    def specs(self, num_classes: int = 2):
        """{"frozen": SpecTree, "lora": SpecTree} for this model."""
        raise NotImplementedError

    def lora_specs(self, num_classes: int = 2):
        """The trainable (uplinked) LoRA subtree — what Eq. 22's
        |θ_LoRA| term prices."""
        return self.specs(num_classes)["lora"]

    # -- split execution ----------------------------------------------------
    def embed(self, frozen, tokens):
        """Token ids (B, S) -> block-stack input activations."""
        raise NotImplementedError

    def run_blocks(self, frozen, lora, x, lo: int, hi: int,
                   mask_valid=None):
        """Run blocks [lo, hi) — the tripartite-split building block."""
        raise NotImplementedError

    def head(self, frozen, lora, x):
        """Block-stack output -> (pooled repr (B, D), task logits)."""
        raise NotImplementedError

    def forward(self, frozen, lora, tokens, mask_valid=None):
        """Full (unsplit) pass: embed -> all blocks -> head."""
        x = self.embed(frozen, tokens)
        x = self.run_blocks(frozen, lora, x, 0, self.num_blocks, mask_valid)
        return self.head(frozen, lora, x)

    def probe_repr(self, frozen, lora, tokens):
        """Pooled embedding of public probes (fingerprints, SS-OP)."""
        return self.forward(frozen, lora, tokens)[0]

    # -- task ---------------------------------------------------------------
    def per_example_loss(self, logits, batch):
        """(B,) per-example loss; weighted-mean'd by the batched engine."""
        raise NotImplementedError

    def accuracy(self, logits, tokens, labels) -> float:
        """Host-side eval metric on a test batch."""
        raise NotImplementedError

    # -- shape / cost facts -------------------------------------------------
    def activation_shape(self, batch: int, seq: int):
        """Shape of an activation crossing a split boundary (pre-sketch);
        the last dim is Eq. 22's D^hidden."""
        return (batch, seq, self.cfg.d_model)

    def block_param_count(self, num_classes: int = 2) -> float:
        """Per-block parameter count (frozen + LoRA), for 6ND FLOPs."""
        specs = self.specs(num_classes)
        total = _spec_params(specs["frozen"]["blocks"])
        lora_blocks = specs["lora"].get("blocks")
        if lora_blocks is not None:
            total += _spec_params(lora_blocks)
        return total / self.num_blocks

    def head_param_count(self, num_classes: int = 2) -> float:
        """Client-side readout parameters outside the block stack."""
        raise NotImplementedError

    def flops_per_token(self, split=None, num_classes: int = 2) -> float:
        """6ND training FLOPs per token.

        ``split=None`` counts the full model; a tripartite
        :class:`~repro.core.split_training.Split` counts only the
        client-side parts (Part 1's ``p`` + Part 3's ``o`` blocks plus
        the head) — what the device itself executes and is billed for.
        """
        blk = self.block_param_count(num_classes)
        head = self.head_param_count(num_classes)
        n_blocks = (self.num_blocks if split is None
                    else split.p + split.o)
        return 6.0 * (n_blocks * blk + head)


# ---------------------------------------------------------------------------
# adapters
# ---------------------------------------------------------------------------

class BertSplitModel(SplitModel):
    """The paper's own model (§IV.A): post-LN encoder, [CLS] pooler +
    classification head (both trainable alongside the LoRA adapters).

    ``pooling`` selects the readout: ``"cls"`` (position 0 through the
    tanh pooler, the paper's convention — requires the [CLS] token to
    carry attention-mixed sequence signal) or ``"mean"`` (mean over
    positions straight into the linear classifier — the friendlier
    readout the convergence study uses: every position's
    class-conditional unigram evidence reaches the logits directly,
    matching how the causal-LM family already mean-pools its probe
    representations, and the saturating tanh pooler — which caps the
    usable head lr — drops out of the gradient path).
    """

    task = "classification"

    def __init__(self, cfg: ArchConfig, pooling: str = "cls"):
        if pooling not in ("cls", "mean"):
            raise ValueError(f"unknown pooling {pooling!r}")
        super().__init__(cfg)
        self.pooling = pooling

    def with_pooling(self, pooling: str) -> "BertSplitModel":
        return type(self)(self.cfg, pooling)

    def specs(self, num_classes: int = 2):
        specs = bert_mod.bert_specs(self.cfg, num_classes)
        if self.pooling == "mean":
            # zero-init the linear classifier: the mean-pool readout is
            # logits = mean(x) @ W + b, and a large random W makes the
            # model ride the random-init function (it memorizes the
            # training shard along random directions and generalizes at
            # chance).  Starting at W=0 the head learns the actual
            # class-mean geometry.  ("cls" keeps the historical random
            # init — golden-pinned.)
            w = specs["lora"]["head"]["w"]
            specs["lora"]["head"]["w"] = w._replace(init="zeros")
        return specs

    def embed(self, frozen, tokens):
        return bert_mod.embed(self.cfg, frozen, tokens)

    def run_blocks(self, frozen, lora, x, lo: int, hi: int,
                   mask_valid=None):
        return bert_mod.run_blocks(self.cfg, frozen, lora, x, lo, hi,
                                   mask_valid)

    def head(self, frozen, lora, x):
        if self.pooling == "mean":
            src = x.mean(axis=1)
            logits = src @ lora["head"]["w"].astype(src.dtype) \
                + lora["head"]["b"].astype(src.dtype)
            return src, logits
        cls = x[:, 0, :]
        pooled = jnp.tanh(cls @ lora["pooler"]["w"].astype(cls.dtype)
                          + lora["pooler"]["b"].astype(cls.dtype))
        logits = pooled @ lora["head"]["w"].astype(cls.dtype) \
            + lora["head"]["b"].astype(cls.dtype)
        return cls, logits

    def per_example_loss(self, logits, batch):
        return per_example_ce(logits, batch["labels"])

    def accuracy(self, logits, tokens, labels) -> float:
        pred = np.asarray(jnp.argmax(logits, -1))
        return float((pred == np.asarray(labels)).mean())

    def head_param_count(self, num_classes: int = 2) -> float:
        lora = self.lora_specs(num_classes)
        return _spec_params(lora["pooler"]) + _spec_params(lora["head"])


class CausalLMSplitModel(SplitModel):
    """Dense decoder-only causal LM (llama/qwen/olmo-style zoo configs).

    The task head is the (frozen) vocab projection; the per-example loss
    is mean next-token CE with padded-vocab masking, and the pooled
    representation for fingerprints is the mean final hidden state.
    MoE / prefix-structured decoders are rejected: their layer stacks are
    not uniform block slices, so Eq. 7's p/q/o arithmetic doesn't apply
    as-is (a future adapter can map them).
    """

    task = "causal-lm"

    def __init__(self, cfg: ArchConfig):
        if cfg.family != "dense" or cfg.moe is not None:
            raise ValueError(
                f"CausalLMSplitModel needs a dense non-MoE decoder config; "
                f"got family={cfg.family!r} moe={cfg.moe is not None}")
        super().__init__(cfg)

    def specs(self, num_classes: int = 2):
        del num_classes   # LM head is the vocab projection, not a classifier
        return transformer.lm_specs(self.cfg)

    def embed(self, frozen, tokens):
        return jnp.take(frozen["embed"], tokens,
                        axis=0).astype(self.cfg.adtype())

    def run_blocks(self, frozen, lora, x, lo: int, hi: int,
                   mask_valid=None):
        x = transformer.run_block_range(self.cfg, frozen, lora, x, lo, hi)
        if mask_valid is not None:
            x = x * mask_valid[..., None].astype(x.dtype)
        return x

    def head(self, frozen, lora, x):
        x = apply_norm(self.cfg.norm, frozen["final_norm"], x)
        head = frozen.get("head", None)
        logits = (x @ frozen["embed"].T.astype(x.dtype) if head is None
                  else x @ head.astype(x.dtype))
        return x.mean(axis=1), logits

    def per_example_loss(self, logits, batch):
        tokens = batch["tokens"]
        lg = logits[:, :-1, :].astype(
            jnp.promote_types(logits.dtype, jnp.float32))
        vp, V = lg.shape[-1], self.cfg.vocab_size
        if vp > V:
            lg = lg + jnp.where(jnp.arange(vp) < V, 0.0,
                                -1e30).astype(lg.dtype)
        targets = tokens[:, 1:]
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - gold, axis=-1)

    def accuracy(self, logits, tokens, labels) -> float:
        del labels                       # next-token top-1, not class labels
        # argmax on device: transfer (B, S) ints, not (B, S, vocab) floats
        pred = np.asarray(
            jnp.argmax(logits[:, :-1, :self.cfg.vocab_size], -1))
        targets = np.asarray(tokens)[:, 1:]
        return float((pred == targets).mean())

    def head_param_count(self, num_classes: int = 2) -> float:
        frozen = self.specs()["frozen"]
        total = _spec_params(frozen["final_norm"])
        if "head" in frozen:
            total += _spec_params(frozen["head"])
        else:                            # tied embeddings: output reuses embed
            total += float(np.prod(frozen["embed"].shape))
        return total


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

#: family -> adapter class, consulted by :func:`split_model_for`.
#: Extend with :func:`register_family_adapter` to make a new family
#: split-federable wherever an ``ArchConfig`` is adapted directly
#: (cost/communication models, back-compat shims).
FAMILY_ADAPTERS: Dict[str, Callable[[ArchConfig], "SplitModel"]] = {}


def register_family_adapter(family: str,
                            adapter: Callable[[ArchConfig], "SplitModel"]
                            ) -> None:
    FAMILY_ADAPTERS[family] = adapter


def _adapter_for(cfg: ArchConfig):
    adapter = FAMILY_ADAPTERS.get(cfg.family)
    if adapter is None:
        raise NotImplementedError(
            f"no SplitModel adapter for arch {cfg.name!r} (family "
            f"{cfg.family!r}); subclass SplitModel and add it with "
            f"register_family_adapter({cfg.family!r}, <adapter>) — see "
            f"docs/models.md")
    return adapter


def _dense_adapter(cfg: ArchConfig) -> "SplitModel":
    # CausalLMSplitModel itself rejects MoE/prefix configs with a
    # targeted error; reaching it is the right failure mode
    return CausalLMSplitModel(cfg)


register_family_adapter("encoder", BertSplitModel)
register_family_adapter("dense", _dense_adapter)


@lru_cache(maxsize=None)
def split_model_for(cfg: ArchConfig) -> SplitModel:
    """Adapt an existing ``ArchConfig`` (cached per config)."""
    return _adapter_for(cfg)(cfg)


def as_split_model(obj: Union[SplitModel, ArchConfig]) -> SplitModel:
    """SplitModel passthrough / ArchConfig adaptation (back-compat shim
    for callers that still pass a config where a model is expected)."""
    return obj if isinstance(obj, SplitModel) else split_model_for(obj)


#: name -> arch id in repro.configs.REGISTRY, or a factory
#: (num_layers=None, dtype=None, **overrides) -> SplitModel
_REGISTRY: Dict[str, Union[str, Callable[..., SplitModel]]] = {}


def register_split_model(name: str,
                         target: Union[str, Callable[..., SplitModel],
                                       None] = None) -> None:
    """Register ``name`` for :func:`get_split_model`.

    ``target`` is an arch id from ``repro.configs.REGISTRY`` (defaults
    to ``name``) or a callable ``(num_layers=None, dtype=None,
    **overrides) -> SplitModel`` for custom adapters.
    """
    _REGISTRY[name] = target if target is not None else name


def available_split_models():
    return sorted(_REGISTRY)


def get_split_model(name: str, *, num_layers: Optional[int] = None,
                    dtype: Optional[str] = None, reduced: bool = True,
                    pooling: Optional[str] = None,
                    **overrides) -> SplitModel:
    """Resolve a registered architecture name to a ``SplitModel``.

    By default the arch config is ``reduced()`` (the federation runs
    CPU-sized models) and then overridden with ``num_layers`` / ``dtype``
    / any ``ArchConfig.with_`` keyword.  ``pooling`` selects a readout
    variant on adapters that support one (the encoder family's
    ``"cls"``/``"mean"``); passing it for a family without pooling
    options is an error.
    """
    if name not in _REGISTRY:
        raise KeyError(f"unknown split model {name!r}; registered: "
                       f"{available_split_models()}")
    target = _REGISTRY[name]
    if callable(target):
        m = target(num_layers=num_layers, dtype=dtype, **overrides)
    else:
        cfg = get_config(target)
        if reduced:
            cfg = cfg.reduced()
        kw = dict(overrides)
        if num_layers is not None:
            kw["num_layers"] = num_layers
        if dtype is not None:
            kw.setdefault("param_dtype", dtype)
            kw.setdefault("activation_dtype", dtype)
        if kw:
            cfg = cfg.with_(**kw)
        m = split_model_for(cfg)
    if pooling is not None:
        if not hasattr(m, "with_pooling"):
            raise ValueError(
                f"model {name!r} ({type(m).__name__}) has no pooling "
                "options; pooling= only applies to the encoder family")
        m = m.with_pooling(pooling)
    return m


# every zoo config with a family adapter is split-federable out of the box
for _arch, _cfg in ARCH_REGISTRY.items():
    if _cfg.family == "encoder" or (_cfg.family == "dense"
                                    and _cfg.moe is None):
        register_split_model(_arch)
del _arch, _cfg
