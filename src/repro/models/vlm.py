"""Llama-3.2-Vision style VLM decoder [hf:meta-llama/Llama-3.2-11B-Vision]:
self-attention blocks with gated cross-attention image layers every 5th
block.  The vision tower is a STUB (assignment carve-out): the model
consumes projected patch embeddings (B, num_vision_tokens, d_model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import (apply_norm, apply_mlp, attn_apply,
                                 gqa_attention, project, out_project,
                                 stack_specs)
from repro.models.params import Spec


def _period(cfg) -> int:
    return cfg.cross_attn_every


def _n_periods(cfg) -> int:
    assert cfg.num_layers % _period(cfg) == 0
    return cfg.num_layers // _period(cfg)


def _cross_block_specs(cfg):
    return {"ln1": common.norm_specs(cfg.norm, cfg.d_model),
            "attn": common.attn_specs(cfg),
            "gate_attn": Spec((), (), "zeros"),
            "ln2": common.norm_specs(cfg.norm, cfg.d_model),
            "mlp": common.mlp_specs(cfg),
            "gate_mlp": Spec((), (), "zeros")}


def vlm_specs(cfg):
    n_self = _period(cfg) - 1
    period_p = {f"l{i}": common.block_specs(cfg) for i in range(n_self)}
    period_p["cross"] = _cross_block_specs(cfg)
    period_l = {f"l{i}": common.block_lora_specs(cfg) for i in range(n_self)}
    period_l["cross"] = {"attn": common.attn_lora_specs(cfg)}
    frozen = {
        "embed": Spec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), "embed"),
        "periods": stack_specs(_n_periods(cfg), period_p),
        "final_norm": common.norm_specs(cfg.norm, cfg.d_model),
        "head": Spec((cfg.d_model, cfg.padded_vocab), ("embed", "vocab")),
    }
    return {"frozen": frozen,
            "lora": {"periods": stack_specs(_n_periods(cfg), period_l)}}


def _cross_block(cfg, p, lp, x, vision=None, kv_cache=None, chunk=2048):
    ls = cfg.lora.alpha / cfg.lora.rank
    xn = apply_norm(cfg.norm, p["ln1"], x)
    q = project(p["attn"], lp["attn"] if lp else None, xn, "q", ls)
    if kv_cache is not None:
        k, v = kv_cache["ck"], kv_cache["cv"]
    else:
        k = project(p["attn"], lp["attn"] if lp else None, vision, "k", ls)
        v = project(p["attn"], lp["attn"] if lp else None, vision, "v", ls)
    o = gqa_attention(q, k, v, causal=False, chunk=chunk)
    h = out_project(p["attn"], lp["attn"] if lp else None, o, x, ls)
    x = x + jnp.tanh(p["gate_attn"].astype(x.dtype)) * h
    f = apply_mlp(cfg, p["mlp"], apply_norm(cfg.norm, p["ln2"], x))
    return x + jnp.tanh(p["gate_mlp"].astype(x.dtype)) * f


def _self_layers(cfg, p, lp, x, *, positions, caches=None, chunk=2048):
    n_self = _period(cfg) - 1
    new = {}
    for i in range(n_self):
        xn = x
        y, nc = common.decoder_block(
            cfg, p[f"l{i}"], lp[f"l{i}"] if lp else None, xn,
            positions=positions,
            cache=caches[f"l{i}"] if caches else None, chunk=chunk)
        x = y
        if caches is not None:
            new[f"l{i}"] = nc
    return x, new


def vlm_forward(cfg, params, lora, tokens, vision, *, remat=True,
                chunk=2048, **_):
    """tokens (B,S), vision (B,Nv,D) stub embeddings -> logits."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype())
    vision = vision.astype(cfg.adtype())
    positions = jnp.arange(S)

    def body(xc, pl):
        p, lp = pl
        xc, _ = _self_layers(cfg, p, lp, xc, positions=positions, chunk=chunk)
        xc = _cross_block(cfg, p["cross"], lp["cross"] if lp else None, xc,
                          vision=vision, chunk=chunk)
        return xc, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, (params["periods"],
                                  lora["periods"] if lora else None))
    x = apply_norm(cfg.norm, params["final_norm"], x)
    return x @ params["head"].astype(x.dtype), jnp.zeros((), jnp.float32)


def vlm_cache_specs(cfg, batch: int, seq_len: int):
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    n_self = _period(cfg) - 1
    per = {f"l{i}": {
        "k": Spec((batch, seq_len, kv, hd), ("batch", None, "kv_heads", None)),
        "v": Spec((batch, seq_len, kv, hd), ("batch", None, "kv_heads", None)),
        "len": Spec((), (), "zeros", 1.0, "int32")} for i in range(n_self)}
    per["cross"] = {"ck": Spec((batch, cfg.num_vision_tokens, kv, hd),
                               ("batch", None, "kv_heads", None)),
                    "cv": Spec((batch, cfg.num_vision_tokens, kv, hd),
                               ("batch", None, "kv_heads", None))}
    return {"periods": stack_specs(_n_periods(cfg), per)}


def vlm_decode_step(cfg, params, lora, cache, tokens, *, chunk=4096, **_):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype())

    def body(xc, pl):
        p, lp, c = pl
        pos = c["l0"]["len"] + jnp.arange(1)
        xc, new = _self_layers(cfg, p, lp, xc, positions=pos, caches=c,
                               chunk=chunk)
        xc = _cross_block(cfg, p["cross"], lp["cross"] if lp else None, xc,
                          kv_cache=c["cross"], chunk=chunk)
        new["cross"] = c["cross"]
        return xc, new

    x, new_periods = jax.lax.scan(
        body, x, (params["periods"], lora["periods"] if lora else None,
                  cache["periods"]))
    x = apply_norm(cfg.norm, params["final_norm"], x)
    return x @ params["head"].astype(x.dtype), {"periods": new_periods}
