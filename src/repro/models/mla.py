"""DeepSeek-V2 Multi-head Latent Attention (MLA) [arXiv:2405.04434].

Prefill/train use the naive (expanded) form; decode uses the *absorbed* form:
queries are projected into the compressed latent space so the KV cache holds
only (c_kv, k_rope) — (kv_lora_rank + rope_dim) per token, shared across all
128 heads — and attention runs MQA-style over the latent cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import Spec
from repro.models.common import rope, gqa_attention, apply_norm, NEG_INF


def mla_specs(cfg):
    a = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = a.nope_head_dim + a.rope_head_dim
    p = {
        "w_dq": Spec((d, a.q_lora_rank), ("embed", "lora_r")),
        "q_norm": {"scale": Spec((a.q_lora_rank,), (None,), "ones")},
        "w_uq": Spec((a.q_lora_rank, h, qk), ("lora_r", "heads", None)),
        "w_dkv": Spec((d, a.kv_lora_rank + a.rope_head_dim), ("embed", None)),
        "kv_norm": {"scale": Spec((a.kv_lora_rank,), (None,), "ones")},
        "w_uk": Spec((a.kv_lora_rank, h, a.nope_head_dim), (None, "heads", None)),
        "w_uv": Spec((a.kv_lora_rank, h, a.v_head_dim), (None, "heads", None)),
        "wo": Spec((h, a.v_head_dim, d), ("heads", None, "embed")),
    }
    return p


def mla_lora_specs(cfg):
    """LoRA adapters on the MLA query/output paths."""
    a, r = cfg.mla, cfg.lora.rank
    d, h = cfg.d_model, cfg.num_heads
    qk = a.nope_head_dim + a.rope_head_dim
    out = {}
    if "q" in cfg.lora.targets:
        out["q_a"] = Spec((d, r), ("embed", "lora_r"))
        out["q_b"] = Spec((r, h, qk), ("lora_r", "heads", None), "zeros")
    if "o" in cfg.lora.targets:
        out["o_a"] = Spec((h, a.v_head_dim, r), ("heads", None, "lora_r"))
        out["o_b"] = Spec((r, d), ("lora_r", "embed"), "zeros")
    return out


def _queries(cfg, p, lp, x, positions):
    a = cfg.mla
    ls = cfg.lora.alpha / cfg.lora.rank
    cq = apply_norm("rmsnorm", p["q_norm"], x @ p["w_dq"].astype(x.dtype))
    q = jnp.einsum("bsr,rhe->bshe", cq, p["w_uq"].astype(x.dtype))
    if lp is not None and "q_a" in lp:
        t = x @ lp["q_a"].astype(x.dtype)
        q = q + jnp.einsum("bsr,rhe->bshe", t, lp["q_b"].astype(x.dtype)) * ls
    q_nope = q[..., : a.nope_head_dim]
    q_rope = rope(q[..., a.nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _out(cfg, p, lp, o, x):
    ls = cfg.lora.alpha / cfg.lora.rank
    y = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(o.dtype))
    if lp is not None and "o_a" in lp:
        t = jnp.einsum("bshe,her->bsr", o, lp["o_a"].astype(o.dtype))
        y = y + (t @ lp["o_b"].astype(o.dtype)) * jnp.asarray(ls, o.dtype)
    return y


def mla_full(cfg, p, lp, x, *, positions, chunk=2048):
    """Train/prefill path (expanded keys/values, causal)."""
    a = cfg.mla
    B, S, D = x.shape
    q_nope, q_rope = _queries(cfg, p, lp, x, positions)

    dkv = x @ p["w_dkv"].astype(x.dtype)
    c_kv = apply_norm("rmsnorm", p["kv_norm"], dkv[..., : a.kv_lora_rank])
    k_rope = rope(dkv[..., None, a.kv_lora_rank:], positions, cfg.rope_theta)

    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uv"].astype(x.dtype))

    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (a.rope_head_dim,))], -1)
    o = gqa_attention(q, k, v, causal=True, q_offset=0, chunk=chunk)
    return _out(cfg, p, lp, o, x)


def mla_decode(cfg, p, lp, x, cache, *, chunk=4096):
    """Absorbed decode: cache holds (c_kv, k_rope); MQA over the latent."""
    a = cfg.mla
    B, S1, D = x.shape  # S1 == 1
    cur = cache["len"]
    positions = cur + jnp.arange(S1)
    q_nope, q_rope = _queries(cfg, p, lp, x, positions)

    dkv = x @ p["w_dkv"].astype(x.dtype)
    c_kv_new = apply_norm("rmsnorm", p["kv_norm"], dkv[..., : a.kv_lora_rank])
    k_rope_new = rope(dkv[..., None, a.kv_lora_rank:], positions,
                      cfg.rope_theta)[:, :, 0, :]

    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), cur, 1)
    cr = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), cur, 1)

    # absorb W_uk into q:  score = <W_uk^T q_nope, c_kv> + <q_rope, k_rope>
    q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, p["w_uk"].astype(x.dtype))
    q_eff = jnp.concatenate([q_lat, q_rope], -1)             # (B,1,H,R+rope)
    k_eff = jnp.concatenate([ck, cr], -1)[:, :, None, :]     # (B,S,1,R+rope)

    o_lat = gqa_attention(q_eff, k_eff, ck[:, :, None, :], causal=True,
                          q_offset=cur, kv_valid=cur + S1, chunk=chunk,
                          scale=(a.nope_head_dim + a.rope_head_dim) ** -0.5)
    # project latent attention output through W_uv per head
    o = jnp.einsum("bshr,rhe->bshe", o_lat, p["w_uv"].astype(x.dtype))
    new_cache = {"c_kv": ck, "k_rope": cr, "len": cur + S1}
    return _out(cfg, p, lp, o, x), new_cache
