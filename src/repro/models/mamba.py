"""Mamba selective-SSM layer (for Jamba hybrid blocks) [arXiv:2403.19887].

Training/prefill use a chunkwise scan: ``lax.scan`` over chunks of
``cfg.ssm.chunk`` steps, with the within-chunk recurrence solved in closed
form via cumulative log-decays (fp32, chunk kept small so the
``exp(-cum)`` rescaling never overflows).  Decode is a single recurrence
step on the carried (conv_state, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import Spec


def _d_inner(cfg):
    return cfg.ssm.expand * cfg.d_model


def _dt_rank(cfg):
    return cfg.ssm.dt_rank or max(1, -(-cfg.d_model // 16))


def mamba_specs(cfg):
    s = cfg.ssm
    d, di, dr, ds = cfg.d_model, _d_inner(cfg), _dt_rank(cfg), s.d_state
    return {
        "w_in": Spec((d, 2 * di), ("embed", "mlp")),
        "conv_w": Spec((s.d_conv, di), (None, "mlp")),
        "conv_b": Spec((di,), ("mlp",), "zeros"),
        "w_bcdt": Spec((di, 2 * ds + dr), ("mlp", None)),
        "w_dt": Spec((dr, di), (None, "mlp")),
        "b_dt": Spec((di,), ("mlp",), "const", -4.6),   # softplus^-1(0.01)
        "log_a": Spec((di, ds), ("mlp", "state"), "zeros"),  # A = -1
        "d_skip": Spec((di,), ("mlp",), "ones"),
        "w_out": Spec((di, d), ("mlp", "embed")),
    }


def mamba_lora_specs(cfg):
    if "q" not in cfg.lora.targets and "v" not in cfg.lora.targets:
        return {}
    d, di, r = cfg.d_model, _d_inner(cfg), cfg.lora.rank
    return {"in_a": Spec((d, r), ("embed", "lora_r")),
            "in_b": Spec((r, 2 * di), ("lora_r", "mlp"), "zeros")}


def _causal_conv(cfg, p, x, conv_state=None):
    """Depthwise causal conv along time.  x: (B, S, di)."""
    K = cfg.ssm.d_conv
    if conv_state is not None:
        xp = jnp.concatenate([conv_state, x], 1)       # (B, K-1+S, di)
    else:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * p["conv_w"][i].astype(x.dtype)
              for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else jnp.zeros_like(x[:, :0])
    return out + p["conv_b"].astype(x.dtype), new_state


def _ssm_params(cfg, p, xc):
    """Input-dependent (dt, B, C).  xc: (B, L, di) post-conv activations."""
    s = cfg.ssm
    dr = _dt_rank(cfg)
    bcdt = xc @ p["w_bcdt"].astype(xc.dtype)
    b_ssm = bcdt[..., : s.d_state]
    c_ssm = bcdt[..., s.d_state: 2 * s.d_state]
    dt = jax.nn.softplus(
        bcdt[..., 2 * s.d_state:] @ p["w_dt"].astype(xc.dtype)
        + p["b_dt"].astype(xc.dtype))                   # (B, L, di)
    return dt.astype(jnp.float32), b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32)


def _chunk_scan(cfg, p, xc, x_ssm, h0):
    """Within-chunk closed form.  xc: (B, L, di) conv output (gives dt,B,C);
    x_ssm: (B, L, di) the SSM input; h0: (B, di, ds) carry.  fp32 inside."""
    a = -jnp.exp(p["log_a"].astype(jnp.float32))        # (di, ds), negative
    dt, b_ssm, c_ssm = _ssm_params(cfg, p, xc)
    x32 = x_ssm.astype(jnp.float32)
    # decay exponents: e[t] = dt[t] * a  (B,L,di,ds); cumulative over t
    e = dt[..., None] * a                               # (B,L,di,ds)
    cum = jnp.cumsum(e, axis=1)                         # negative, monotone
    # h[t] = exp(cum[t]) * (h0 + sum_{τ<=t} exp(-cum[τ]) dt[τ]B[τ]x[τ])
    u = (dt * x32)[..., None] * b_ssm[:, :, None, :]    # (B,L,di,ds)
    # h[t] = Σ_τ exp(cum[t]-cum[τ]) u[τ]; computed as exp(cum)·cumsum(exp(-cum)u)
    inner = jnp.cumsum(u * jnp.exp(jnp.clip(-cum, None, 60.0)), axis=1)
    h = jnp.exp(cum) * (h0[:, None] + inner)            # (B,L,di,ds)
    y = jnp.einsum("blds,bls->bld", h, c_ssm)
    y = y + x32 * p["d_skip"].astype(jnp.float32)
    return y.astype(x_ssm.dtype), h[:, -1]


def mamba_apply(cfg, p, lp, x, *, cache=None):
    """x: (B, S, D).  cache: {'conv': (B,K-1,di), 'ssm': (B,di,ds)} or None."""
    s = cfg.ssm
    B, S, D = x.shape
    di = _d_inner(cfg)
    xz = x @ p["w_in"].astype(x.dtype)
    if lp is not None and "in_a" in lp:
        xz = xz + ((x @ lp["in_a"].astype(x.dtype)) @ lp["in_b"].astype(x.dtype)
                   ) * jnp.asarray(cfg.lora.alpha / cfg.lora.rank, x.dtype)
    xin, z = xz[..., :di], xz[..., di:]

    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv(cfg, p, xin, conv_state)
    xc = jax.nn.silu(xc)

    h0 = (cache["ssm"].astype(jnp.float32) if cache is not None
          else jnp.zeros((B, di, s.d_state), jnp.float32))

    if S == 1:  # decode: single recurrence step
        dt, b_ssm, c_ssm = _ssm_params(cfg, p, xc)
        a = -jnp.exp(p["log_a"].astype(jnp.float32))
        dec = jnp.exp(dt[:, 0, :, None] * a)            # (B,di,ds)
        h = dec * h0 + (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] \
            * b_ssm[:, 0, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_ssm[:, 0])[:, None, :]
        y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
        y = y.astype(x.dtype)
        h_last = h
    else:
        L = min(s.chunk, S)
        assert S % L == 0, f"S={S} not divisible by chunk={L}"
        nc = S // L
        xcs = xc.reshape(B, nc, L, di).transpose(1, 0, 2, 3)

        def body(h, xc_chunk):
            y, h_new = _chunk_scan(cfg, p, xc_chunk, xc_chunk, h)
            return h_new, y

        h_last, ys = jax.lax.scan(body, h0, xcs)
        y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)

    out = (y * jax.nn.silu(z)) @ p["w_out"].astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssm": h_last.astype(cache["ssm"].dtype)}
    return out, new_cache


def mamba_cache_specs(cfg, batch: int, dtype_tag: str = "cache"):
    s = cfg.ssm
    di = _d_inner(cfg)
    return {"conv": Spec((batch, s.d_conv - 1, di), ("batch", None, "mlp"), "zeros"),
            "ssm": Spec((batch, di, s.d_state), ("batch", "mlp", "state"), "zeros")}
