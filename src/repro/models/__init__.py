"""Pure-JAX model zoo for the ELSA reproduction."""
