"""Unified model-zoo interface.

Every architecture family exposes the same five entry points through
:func:`get_model`:

- ``specs(cfg)``                      -> {'frozen': SpecTree, 'lora': SpecTree}
- ``forward(cfg, frozen, lora, batch, **opts)`` -> (logits, aux)
- ``cache_specs(cfg, batch, seq_len)``-> SpecTree for the decode cache
- ``decode_step(cfg, frozen, lora, cache, tokens, **opts)``
- ``input_specs(cfg, shape)``         -> dict of ShapeDtypeStruct model inputs

plus ``loss`` (next-token CE with padded-vocab masking) and ``train_step``
builders in :mod:`repro.launch.train`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import (transformer, hybrid, ssm_lm, whisper as whisper_mod,
                          vlm as vlm_mod, bert as bert_mod)


class Model(NamedTuple):
    specs: Callable
    forward: Callable            # (cfg, frozen, lora, batch, **opts)
    cache_specs: Optional[Callable]
    decode_step: Optional[Callable]


def _lm_forward(cfg, frozen, lora, batch, **opts):
    return transformer.lm_forward(cfg, frozen, lora, batch["tokens"], **opts)


def _lm_decode(cfg, frozen, lora, cache, batch, **opts):
    return transformer.lm_decode_step(cfg, frozen, lora, cache,
                                      batch["tokens"], **opts)


def _hybrid_forward(cfg, frozen, lora, batch, **opts):
    return hybrid.hybrid_forward(cfg, frozen, lora, batch["tokens"], **opts)


def _hybrid_decode(cfg, frozen, lora, cache, batch, **opts):
    return hybrid.hybrid_decode_step(cfg, frozen, lora, cache,
                                     batch["tokens"], **opts)


def _xlstm_forward(cfg, frozen, lora, batch, **opts):
    return ssm_lm.xlstm_forward(cfg, frozen, lora, batch["tokens"], **opts)


def _xlstm_decode(cfg, frozen, lora, cache, batch, **opts):
    return ssm_lm.xlstm_decode_step(cfg, frozen, lora, cache,
                                    batch["tokens"], **opts)


def _whisper_forward(cfg, frozen, lora, batch, **opts):
    return whisper_mod.whisper_forward(cfg, frozen, lora, batch["tokens"],
                                       batch["frames"], **opts)


def _whisper_decode(cfg, frozen, lora, cache, batch, **opts):
    return whisper_mod.whisper_decode_step(cfg, frozen, lora, cache,
                                           batch["tokens"], **opts)


def _vlm_forward(cfg, frozen, lora, batch, **opts):
    return vlm_mod.vlm_forward(cfg, frozen, lora, batch["tokens"],
                               batch["vision"], **opts)


def _vlm_decode(cfg, frozen, lora, cache, batch, **opts):
    return vlm_mod.vlm_decode_step(cfg, frozen, lora, cache,
                                   batch["tokens"], **opts)


def _bert_forward(cfg, frozen, lora, batch, **opts):
    opts.pop("window", None)
    opts.pop("chunk", None)
    opts.pop("remat", None)
    _, _, logits = bert_mod.bert_forward(cfg, frozen, lora, batch["tokens"],
                                         **opts)
    return logits, jnp.zeros((), jnp.float32)


_FAMILIES: Dict[str, Model] = {
    "dense": Model(transformer.lm_specs, _lm_forward,
                   transformer.lm_cache_specs, _lm_decode),
    "moe": Model(transformer.lm_specs, _lm_forward,
                 transformer.lm_cache_specs, _lm_decode),
    "hybrid": Model(hybrid.hybrid_specs, _hybrid_forward,
                    hybrid.hybrid_cache_specs, _hybrid_decode),
    "ssm": Model(ssm_lm.xlstm_specs, _xlstm_forward,
                 ssm_lm.xlstm_cache_specs, _xlstm_decode),
    "audio": Model(whisper_mod.whisper_specs, _whisper_forward,
                   whisper_mod.whisper_cache_specs, _whisper_decode),
    "vlm": Model(vlm_mod.vlm_specs, _vlm_forward,
                 vlm_mod.vlm_cache_specs, _vlm_decode),
    "encoder": Model(bert_mod.bert_specs, _bert_forward, None, None),
}


def get_model(cfg: ArchConfig) -> Model:
    return _FAMILIES[cfg.family]


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins for the dry-run)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    """Model inputs for (arch, input-shape) as ShapeDtypeStructs."""
    B = shape.global_batch
    adt = cfg.adtype()
    if shape.kind in ("train", "prefill"):
        out = {"tokens": jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32)}
        if cfg.family == "vlm":
            out["vision"] = jax.ShapeDtypeStruct(
                (B, cfg.num_vision_tokens, cfg.d_model), adt)
        if cfg.family == "audio":
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.num_audio_frames, cfg.d_model), adt)
        return out
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def loss_fn(cfg: ArchConfig, logits, tokens, aux=None):
    """Next-token cross entropy with padded-vocab masking.

    The gold logit is extracted with a one-hot contraction (not
    ``take_along_axis``): a gather over the vocab-sharded logits would
    force GSPMD to all-gather the full (B, S, V) tensor, while the one-hot
    multiply-reduce partitions cleanly over the 'model' axis.
    """
    V = cfg.vocab_size
    logits = logits[:, :-1, :].astype(jnp.float32)
    targets = tokens[:, 1:]
    vp = logits.shape[-1]
    if vp > V:
        neg = jnp.where(jnp.arange(vp) < V, 0.0, -1e30).astype(jnp.float32)
        logits = logits + neg
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, vp, dtype=jnp.float32)
    gold = jnp.sum(logits * onehot, axis=-1)
    loss = jnp.mean(lse - gold)
    if aux is not None:
        loss = loss + aux.astype(jnp.float32)
    return loss


def per_example_ce(logits, labels):
    """Per-example cross-entropy (..., C) -> (...); accumulates in at
    least f32 (f64 stays f64 for x64 parity runs)."""
    logits = logits.astype(jnp.promote_types(logits.dtype, jnp.float32))
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - gold


def classification_loss(logits, labels):
    return jnp.mean(per_example_ce(logits, labels))
