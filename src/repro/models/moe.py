"""Mixture-of-Experts FFN (GShard/Switch-style capacity-bounded dispatch).

TPU adaptation: token->expert dispatch is expressed as scatter/gather over an
(E*C, D) buffer (capacity C per expert) so the expert matmuls are dense
einsums on the MXU; no per-token control flow.  The router runs in fp32.

Load-balance auxiliary loss follows Switch Transformer:
``aux = E * sum_e fraction_tokens_e * mean_router_prob_e``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import Spec
from repro.models.common import activation


def moe_specs(cfg):
    m = cfg.moe
    d, f, e = cfg.d_model, m.expert_d_ff or cfg.d_ff, m.num_experts
    p = {
        "router": Spec((d, e), ("embed", "experts")),
        "w_gate": Spec((e, d, f), ("experts", "embed", "mlp")),
        "w_up": Spec((e, d, f), ("experts", "embed", "mlp")),
        "w_down": Spec((e, f, d), ("experts", "mlp", "embed")),
    }
    if m.num_shared_experts:
        fs = f * m.num_shared_experts
        p["shared"] = {
            "w_gate": Spec((d, fs), ("embed", "mlp")),
            "w_up": Spec((d, fs), ("embed", "mlp")),
            "w_down": Spec((fs, d), ("mlp", "embed")),
        }
    return p


def _capacity(m, n_tokens: int) -> int:
    c = int(m.capacity_factor * m.experts_per_token * n_tokens / m.num_experts)
    return max(8, ((c + 7) // 8) * 8)


def moe_apply(cfg, p, x, *, return_aux: bool = True
              ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """x: (B, S, D) -> (B, S, D), aux_loss (scalar fp32)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    k = m.experts_per_token
    E = m.num_experts
    C = _capacity(m, T)
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)                       # (T, E)
    gate_vals, sel = jax.lax.top_k(probs, k)                 # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_e = sel.reshape(-1)                                 # (T*k,)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (T*k, E)
    pos_in_e = ((jnp.cumsum(oh, axis=0) - oh) * oh).sum(-1)  # (T*k,)
    keep = pos_in_e < C
    slot = jnp.where(keep, flat_e * C + pos_in_e, E * C)     # overflow -> dump row

    x_rep = jnp.repeat(xt, k, axis=0)                        # (T*k, D)
    buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot].add(x_rep)
    buf = buf[:-1].reshape(E, C, D)

    g = activation(cfg.act, jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    eo = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"].astype(x.dtype))
    eo = jnp.concatenate([eo.reshape(E * C, D), jnp.zeros((1, D), x.dtype)])

    out_rep = eo[slot] * keep[:, None].astype(x.dtype)       # (T*k, D)
    out = (out_rep.reshape(T, k, D) *
           gate_vals[..., None].astype(x.dtype)).sum(1)      # (T, D)

    if "shared" in p:
        sp = p["shared"]
        sg = activation(cfg.act, xt @ sp["w_gate"].astype(x.dtype))
        out = out + (sg * (xt @ sp["w_up"].astype(x.dtype))) @ sp["w_down"].astype(x.dtype)

    aux = None
    if return_aux:
        frac = jnp.mean(jax.nn.one_hot(sel[:, 0], E, dtype=jnp.float32), 0)
        mean_prob = jnp.mean(probs, 0)
        aux = E * jnp.sum(frac * mean_prob) * m.aux_loss_weight
    return out.reshape(B, S, D), aux
