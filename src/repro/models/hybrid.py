"""Jamba-style hybrid LM [arXiv:2403.19887]: Mamba + attention 1:7
interleave with MoE every other layer.

The 32-layer stack is organised as 4 periods of 8 layers
(attention at in-period index 4, MoE FFN on odd in-period indices);
periods are stacked and scanned.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common, moe as moe_lib, mamba as mamba_lib
from repro.models.common import apply_norm, apply_mlp, stack_specs
from repro.models.params import Spec


def _period(cfg) -> int:
    return cfg.attn_every


def _n_periods(cfg) -> int:
    assert cfg.num_layers % _period(cfg) == 0
    return cfg.num_layers // _period(cfg)


def _layer_spec(cfg, kind: str, use_moe: bool):
    p = {"ln1": common.norm_specs(cfg.norm, cfg.d_model),
         "ln2": common.norm_specs(cfg.norm, cfg.d_model)}
    p["inner"] = (common.attn_specs(cfg) if kind == "attn"
                  else mamba_lib.mamba_specs(cfg))
    if use_moe:
        p["moe"] = moe_lib.moe_specs(cfg)
    else:
        p["mlp"] = common.mlp_specs(cfg)
    return p


def _layer_lora_spec(cfg, kind: str):
    return {"inner": (common.attn_lora_specs(cfg) if kind == "attn"
                      else mamba_lib.mamba_lora_specs(cfg))}


def _period_kinds(cfg):
    per = _period(cfg)
    kinds = []
    for i in range(per):
        kind = "attn" if i == per // 2 else "mamba"
        use_moe = cfg.moe is not None and i % cfg.moe.every == 1
        kinds.append((kind, use_moe))
    return kinds


def hybrid_specs(cfg):
    kinds = _period_kinds(cfg)
    period_p = {f"l{i}": _layer_spec(cfg, k, m) for i, (k, m) in enumerate(kinds)}
    period_l = {f"l{i}": _layer_lora_spec(cfg, k) for i, (k, _) in enumerate(kinds)}
    frozen = {
        "embed": Spec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), "embed"),
        "periods": stack_specs(_n_periods(cfg), period_p),
        "final_norm": common.norm_specs(cfg.norm, cfg.d_model),
        "head": Spec((cfg.d_model, cfg.padded_vocab), ("embed", "vocab")),
    }
    return {"frozen": frozen, "lora": {"periods": stack_specs(_n_periods(cfg), period_l)}}


def _apply_layer(cfg, kind, use_moe, p, lp, x, *, positions, cache=None,
                 window=0, chunk=2048):
    xn = apply_norm(cfg.norm, p["ln1"], x)
    if kind == "attn":
        h, nc = common.attn_apply(cfg, p["inner"],
                                  lp["inner"] if lp else None, xn,
                                  positions=positions, cache=cache,
                                  window=window, chunk=chunk)
    else:
        h, nc = mamba_lib.mamba_apply(cfg, p["inner"],
                                      lp["inner"] if lp else None, xn,
                                      cache=cache)
    x = x + h
    xn = apply_norm(cfg.norm, p["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if use_moe:
        f, aux = moe_lib.moe_apply(cfg, p["moe"], xn)
    else:
        f = apply_mlp(cfg, p["mlp"], xn)
    return x + f, nc, aux


def hybrid_forward(cfg, params, lora, tokens, *, window=0, chunk=2048,
                   remat=True):
    kinds = _period_kinds(cfg)
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype())
    positions = jnp.arange(S)

    def body(carry, pl):
        xc, aux_acc = carry
        p, lp = pl
        for i, (kind, use_moe) in enumerate(kinds):
            xc, _, aux = _apply_layer(cfg, kind, use_moe, p[f"l{i}"],
                                      lp[f"l{i}"] if lp else None, xc,
                                      positions=positions, window=window,
                                      chunk=chunk)
            aux_acc = aux_acc + aux
        return (xc, aux_acc), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["periods"], lora["periods"] if lora else None))
    x = apply_norm(cfg.norm, params["final_norm"], x)
    return x @ params["head"].astype(x.dtype), aux


def hybrid_cache_specs(cfg, batch: int, seq_len: int):
    kinds = _period_kinds(cfg)
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    per = {}
    for i, (kind, _) in enumerate(kinds):
        if kind == "attn":
            per[f"l{i}"] = {
                "k": Spec((batch, seq_len, kv, hd), ("batch", None, "kv_heads", None)),
                "v": Spec((batch, seq_len, kv, hd), ("batch", None, "kv_heads", None)),
                "len": Spec((), (), "zeros", 1.0, "int32")}
        else:
            per[f"l{i}"] = mamba_lib.mamba_cache_specs(cfg, batch)
    return {"periods": stack_specs(_n_periods(cfg), per)}


def hybrid_decode_step(cfg, params, lora, cache, tokens, *, window=0,
                       chunk=4096):
    kinds = _period_kinds(cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype())

    def body(xc, pl):
        p, lp, c = pl
        ncs = {}
        for i, (kind, use_moe) in enumerate(kinds):
            ci = c[f"l{i}"]
            pos = (ci["len"] + jnp.arange(1)) if kind == "attn" else jnp.arange(1)
            xc, nc, _ = _apply_layer(cfg, kind, use_moe, p[f"l{i}"],
                                     lp[f"l{i}"] if lp else None, xc,
                                     positions=pos, cache=ci, window=window,
                                     chunk=chunk)
            ncs[f"l{i}"] = nc
        return xc, ncs

    x, new_periods = jax.lax.scan(
        body, x, (params["periods"], lora["periods"] if lora else None,
                  cache["periods"]))
    x = apply_norm(cfg.norm, params["final_norm"], x)
    return x @ params["head"].astype(x.dtype), {"periods": new_periods}
