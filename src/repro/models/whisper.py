"""Whisper-small backbone [arXiv:2212.04356]: 12-layer bidirectional audio
encoder + 12-layer decoder with cross-attention.

The mel + conv frontend is a STUB (assignment carve-out): the model consumes
pre-computed frame embeddings (B, num_audio_frames, d_model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import (apply_norm, apply_mlp, attn_apply,
                                 gqa_attention, project, out_project,
                                 stack_specs)
from repro.models.params import Spec


def _enc_block_specs(cfg):
    return {"ln1": common.norm_specs(cfg.norm, cfg.d_model),
            "attn": common.attn_specs(cfg),
            "ln2": common.norm_specs(cfg.norm, cfg.d_model),
            "mlp": common.mlp_specs(cfg)}


def _dec_block_specs(cfg):
    return {"ln1": common.norm_specs(cfg.norm, cfg.d_model),
            "self": common.attn_specs(cfg),
            "ln_x": common.norm_specs(cfg.norm, cfg.d_model),
            "cross": common.attn_specs(cfg),
            "ln2": common.norm_specs(cfg.norm, cfg.d_model),
            "mlp": common.mlp_specs(cfg)}


def _dec_lora_specs(cfg):
    return {"self": common.attn_lora_specs(cfg),
            "cross": common.attn_lora_specs(cfg)}


def whisper_specs(cfg):
    d = cfg.d_model
    frozen = {
        "embed": Spec((cfg.padded_vocab, d), ("vocab", "embed"), "embed"),
        "pos": Spec((cfg.max_position_embeddings, d), (None, "embed"), "embed"),
        "enc_pos": Spec((cfg.num_audio_frames, d), (None, "embed"), "embed"),
        "enc_blocks": stack_specs(cfg.encoder_layers, _enc_block_specs(cfg)),
        "enc_norm": common.norm_specs(cfg.norm, d),
        "dec_blocks": stack_specs(cfg.num_layers, _dec_block_specs(cfg)),
        "dec_norm": common.norm_specs(cfg.norm, d),
    }
    lora = {
        "enc_blocks": stack_specs(cfg.encoder_layers,
                                  {"attn": common.attn_lora_specs(cfg)}),
        "dec_blocks": stack_specs(cfg.num_layers, _dec_lora_specs(cfg)),
    }
    return {"frozen": frozen, "lora": lora}


def _cross_apply(cfg, p, lp, x, enc_out=None, kv_cache=None, chunk=2048):
    ls = cfg.lora.alpha / cfg.lora.rank
    q = project(p, lp, x, "q", ls)
    if kv_cache is not None:
        k, v = kv_cache["ck"], kv_cache["cv"]
    else:
        k = project(p, lp, enc_out, "k", ls)
        v = project(p, lp, enc_out, "v", ls)
    o = gqa_attention(q, k, v, causal=False, chunk=chunk)
    return out_project(p, lp, o, x, ls)


def encode(cfg, params, lora, frames, *, remat=True, chunk=2048):
    """frames: (B, F, D) stub embeddings -> encoder output (B, F, D)."""
    x = frames.astype(cfg.adtype()) + params["enc_pos"][None].astype(cfg.adtype())
    positions = jnp.arange(frames.shape[1])

    def body(xc, pl):
        p, lp = pl
        h, _ = attn_apply(cfg, p["attn"], lp["attn"] if lp else None,
                          apply_norm(cfg.norm, p["ln1"], xc),
                          positions=positions, causal=False, chunk=chunk)
        xc = xc + h
        xc = xc + apply_mlp(cfg, p["mlp"], apply_norm(cfg.norm, p["ln2"], xc))
        return xc, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, (params["enc_blocks"],
                                  lora["enc_blocks"] if lora else None))
    return apply_norm(cfg.norm, params["enc_norm"], x)


def _dec_block(cfg, p, lp, x, enc_out, *, positions, cache=None, chunk=2048):
    h, nc = attn_apply(cfg, p["self"], lp["self"] if lp else None,
                       apply_norm(cfg.norm, p["ln1"], x),
                       positions=positions,
                       cache=cache["self"] if cache else None, chunk=chunk)
    x = x + h
    x = x + _cross_apply(cfg, p["cross"], lp["cross"] if lp else None,
                         apply_norm(cfg.norm, p["ln_x"], x), enc_out=enc_out,
                         kv_cache=cache["cross"] if cache else None,
                         chunk=chunk)
    x = x + apply_mlp(cfg, p["mlp"], apply_norm(cfg.norm, p["ln2"], x))
    return x, ({"self": nc, "cross": cache["cross"]} if cache else None)


def whisper_forward(cfg, params, lora, tokens, frames, *, remat=True,
                    chunk=2048, **_):
    """Training/prefill: tokens (B,S) + frames (B,F,D) -> logits."""
    enc_out = encode(cfg, params, lora, frames, remat=remat, chunk=chunk)
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype())
    x = x + params["pos"][:S][None].astype(x.dtype)
    positions = jnp.arange(S)

    def body(xc, pl):
        p, lp = pl
        y, _ = _dec_block(cfg, p, lp, xc, enc_out, positions=positions,
                          chunk=chunk)
        return y, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, (params["dec_blocks"],
                                  lora["dec_blocks"] if lora else None))
    x = apply_norm(cfg.norm, params["dec_norm"], x)
    return x @ params["embed"].T.astype(x.dtype), jnp.zeros((), jnp.float32)


def whisper_cache_specs(cfg, batch: int, seq_len: int):
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    one = {
        "self": {"k": Spec((batch, seq_len, kv, hd), ("batch", None, "kv_heads", None)),
                 "v": Spec((batch, seq_len, kv, hd), ("batch", None, "kv_heads", None)),
                 "len": Spec((), (), "zeros", 1.0, "int32")},
        "cross": {"ck": Spec((batch, cfg.num_audio_frames, kv, hd),
                             ("batch", None, "kv_heads", None)),
                  "cv": Spec((batch, cfg.num_audio_frames, kv, hd),
                             ("batch", None, "kv_heads", None))},
    }
    return {"dec_blocks": stack_specs(cfg.num_layers, one)}


def whisper_prefill_cache(cfg, params, lora, frames, batch: int, seq_len: int):
    """Build a decode cache with the cross k/v computed from the encoder."""
    enc_out = encode(cfg, params, lora, frames, remat=False)
    ls = cfg.lora.alpha / cfg.lora.rank
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def per_layer(p, lp):
        ck = project(p["cross"], lp["cross"] if lp else None, enc_out, "k", ls)
        cv = project(p["cross"], lp["cross"] if lp else None, enc_out, "v", ls)
        return ck, cv

    cks, cvs = jax.vmap(per_layer, in_axes=(0, 0))(
        params["dec_blocks"], lora["dec_blocks"] if lora else None)
    L = cfg.num_layers
    zeros_k = jnp.zeros((L, batch, seq_len, kv, hd), cfg.adtype())
    return {"dec_blocks": {
        "self": {"k": zeros_k, "v": zeros_k,
                 "len": jnp.zeros((L,), jnp.int32)},
        "cross": {"ck": cks.astype(cfg.adtype()), "cv": cvs.astype(cfg.adtype())},
    }}


def whisper_decode_step(cfg, params, lora, cache, tokens, *, chunk=4096, **_):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype())

    def body(xc, pl):
        p, lp, c = pl
        pos = c["self"]["len"] + jnp.arange(1)
        y, nc = _dec_block(cfg, p, lp, xc, None, positions=pos, cache=c,
                           chunk=chunk)
        return y, nc

    # add positional embedding once (shared absolute position)
    pos0 = cache["dec_blocks"]["self"]["len"][0]
    x = x + jax.lax.dynamic_slice_in_dim(params["pos"], pos0, 1, 0)[None, 0:1].astype(x.dtype)

    x, new_blocks = jax.lax.scan(
        body, x, (params["dec_blocks"], lora["dec_blocks"] if lora else None,
                  cache["dec_blocks"]))
    x = apply_norm(cfg.norm, params["dec_norm"], x)
    return x @ params["embed"].T.astype(x.dtype), {"dec_blocks": new_blocks}
