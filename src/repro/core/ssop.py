"""Semantic Subspace Orthogonal Perturbation (ELSA §III.B.3, Eqs. 17–19).

``Q_n = U_n V_n U_nᵀ + (I - U_n U_nᵀ)`` rotates only inside the top-r
semantic subspace U_n of recent hidden activations, with a client-secret
orthogonal V_n (QR of a seeded Gaussian).  Q_n is orthogonal, so the
backward pass restores exact gradients via Q_nᵀ.

TPU adaptation (DESIGN.md §3): Q_n (D×D) is never materialized; we apply
the fused low-rank form  ``H Q_nᵀ = H + (H U_n) (V_nᵀ - I) U_nᵀ`` —
O(T·D·r) instead of O(T·D²).
"""
from __future__ import annotations

import hashlib
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class SSOP(NamedTuple):
    u: jnp.ndarray   # (D, r) orthonormal semantic basis
    v: jnp.ndarray   # (r, r) secret orthogonal rotation
    # Fused update matrices, precomputed once per channel by ``make_ssop``
    # so the forward (and its VJP) never re-materializes the r×r identity
    # subtraction per call: w = Vᵀ - I, w_inv = V - I.
    w: Optional[jnp.ndarray] = None
    w_inv: Optional[jnp.ndarray] = None


def semantic_subspace(j_matrix: jnp.ndarray, r: int) -> jnp.ndarray:
    """Eq. 17: top-r right singular vectors of J (Q, D) -> U (D, r)."""
    j32 = j_matrix.astype(jnp.float32)
    _, _, vt = jnp.linalg.svd(j32, full_matrices=False)
    return vt[:r].T


def client_seed(salt: str, client_id: int) -> int:
    """seed_n = Hash(s || n) (Eq. 18)."""
    h = hashlib.sha256(f"{salt}||{client_id}".encode()).digest()
    return int.from_bytes(h[:8], "little")


def random_orthogonal(r: int, seed: int) -> jnp.ndarray:
    """Eq. 18: V_n = QR(Phi(n)), Phi ~ N(0,1) seeded."""
    rng = np.random.default_rng(seed)
    phi = rng.standard_normal((r, r))
    q, rr = np.linalg.qr(phi)
    # sign-fix so the decomposition is unique (det-stable)
    q = q * np.sign(np.diagonal(rr))[None, :]
    return jnp.asarray(q, jnp.float32)


def make_ssop_from_basis(u: jnp.ndarray, salt: str,
                         client_id: int) -> SSOP:
    """SSOP from a precomputed semantic basis ``U``.

    Only the seeded ``V_n`` rotation is per-identity (Eq. 18 keys it on
    the client id, not on the execution slot), so callers that manage
    many identities over one shared basis — the population channel LRU —
    pay the SVD once and a (r, r) QR per identity.  The seed depends on
    nothing but ``(salt, client_id)``, which is what makes an evicted
    identity's rotation regenerate bit-exactly.
    """
    r = u.shape[1]
    v = random_orthogonal(r, client_seed(salt, client_id))
    eye = jnp.eye(r, dtype=v.dtype)
    return SSOP(u=u, v=v, w=v.T - eye, w_inv=v - eye)


def make_ssop(j_matrix: jnp.ndarray, r: int, salt: str,
              client_id: int) -> SSOP:
    return make_ssop_from_basis(semantic_subspace(j_matrix, r), salt,
                                client_id)


def apply_ssop(h: jnp.ndarray, ssop: SSOP, *, use_kernel: bool = False
               ) -> jnp.ndarray:
    """H -> H Q_nᵀ (rows are feature vectors).  Fused low-rank form."""
    if use_kernel:
        from repro.kernels.ssop import ops as kops
        return kops.ssop_apply(h, ssop.u, ssop.v, w=ssop.w)
    u = ssop.u.astype(h.dtype)
    w = ssop.w if ssop.w is not None \
        else ssop.v.T - jnp.eye(ssop.v.shape[0], dtype=ssop.v.dtype)
    proj = h @ u                                       # (..., r)
    return h + (proj @ w.astype(h.dtype)) @ u.T


def apply_ssop_inverse(h: jnp.ndarray, ssop: SSOP) -> jnp.ndarray:
    """H -> H Q_n (the exact inverse; Q orthogonal)."""
    u = ssop.u.astype(h.dtype)
    w = ssop.w_inv if ssop.w_inv is not None \
        else ssop.v - jnp.eye(ssop.v.shape[0], dtype=ssop.v.dtype)
    proj = h @ u
    return h + (proj @ w.astype(h.dtype)) @ u.T


def q_matrix(ssop: SSOP) -> jnp.ndarray:
    """Explicit Q_n (tests only — O(D²))."""
    d, r = ssop.u.shape
    uu = ssop.u @ ssop.u.T
    return ssop.u @ ssop.v @ ssop.u.T + jnp.eye(d, dtype=ssop.u.dtype) - uu
