"""Server-side update screening and the per-client trust EMA.

ELSA computes prediction-consistency trust scores once, at clustering
time (:mod:`repro.core.trust`), and never consults them again.  This
module makes trust a *live* server-side quantity (docs/robustness.md):

- :class:`TrustLedger` keeps one trust score per client, seeded from the
  clustering-time prediction-consistency scores and updated as an EMA of
  screening outcomes (pass -> pull toward 1, fail -> pull toward 0), so
  a client that repeatedly ships garbage loses aggregation weight even
  when an individual bad update slips past the per-round checks.
- :func:`screen_updates` applies three per-round checks to a cohort of
  incoming adapter updates, judged on their *deltas* against the edge
  model they were trained from: a finite check (NaN/Inf anywhere fails),
  a norm screen (delta norm > ``norm_k`` x the cohort's median finite
  delta norm), and a direction screen (cosine against the cohort's
  weighted-mean delta below ``cos_min`` — the only cheap check that
  catches sign-flipped Byzantine updates, whose norms are
  indistinguishable from honest ones).
- :func:`screen_and_aggregate` drops failing updates, down-weights the
  survivors by their trust scores, excludes clients whose trust EMA sank
  below ``trust_floor``, and — when screening leaves too small a cohort
  to trust a plain mean — falls back to a coordinate-wise trimmed mean
  over the finite updates (Yin et al. 2018-style robustness without
  per-client attribution).

Everything here is only reached when ``FedConfig.screen`` is on; the
disabled path never imports this module's math, keeping golden-pinned
histories bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import telemetry as tm
from repro.core import aggregation as agg

# screening verdicts, per update
OK = "ok"
NONFINITE = "nonfinite"
NORM = "norm"
FLIP = "flip"
LOW_TRUST = "low-trust"


@dataclasses.dataclass(frozen=True)
class ScreeningConfig:
    """Thresholds of the per-round screening stage (see module doc)."""
    norm_k: float = 4.0        # reject ||delta|| > norm_k * median finite
    cos_min: float = -0.5      # reject cos(delta, cohort mean) < cos_min
    trust_floor: float = 0.15  # exclude clients whose trust EMA sank below
    min_cohort: int = 2        # fewer survivors -> trimmed-mean fallback
    trim_frac: float = 0.25    # per-side trim of the fallback mean


class TrustLedger:
    """Per-client trust EMA over screening outcomes.

    ``scores`` start at 1 (or the clustering-time prediction-consistency
    scores via :meth:`seed`) and move by
    ``score <- beta * score + (1 - beta) * outcome`` with outcome 1 on a
    passed screen and 0 on a failed one.
    """

    def __init__(self, n_clients: int, beta: float = 0.7):
        if not 0.0 <= beta <= 1.0:
            raise ValueError(f"trust beta must be in [0, 1], got {beta}")
        self.beta = float(beta)
        self.scores = np.ones(n_clients, np.float64)
        self.passes = np.zeros(n_clients, np.int64)
        self.fails = np.zeros(n_clients, np.int64)

    def seed(self, trust: np.ndarray) -> None:
        """Adopt clustering-time trust scores as the EMA starting point."""
        self.scores = np.clip(np.asarray(trust, np.float64), 1e-6, 1.0).copy()

    def record(self, client: int, passed: bool) -> None:
        b = self.beta
        self.scores[client] = b * self.scores[client] \
            + (1.0 - b) * (1.0 if passed else 0.0)
        if passed:
            self.passes[client] += 1
        else:
            self.fails[client] += 1

    def weight(self, client: int) -> float:
        return float(self.scores[client])

    # -- checkpoint plumbing ------------------------------------------------
    def state(self) -> Dict:
        return {"beta": self.beta, "scores": self.scores,
                "passes": self.passes, "fails": self.fails}

    def load_state(self, state: Dict) -> None:
        self.beta = float(state["beta"])
        self.scores = np.asarray(state["scores"], np.float64).copy()
        self.passes = np.asarray(state["passes"], np.int64).copy()
        self.fails = np.asarray(state["fails"], np.int64).copy()


@dataclasses.dataclass
class ScreenReport:
    """One screening pass: per-update verdicts + what was aggregated."""
    clients: List[int]
    verdicts: List[str]            # parallel to ``clients``
    kept: List[int]                # indices into the cohort that aggregated
    fallback: str = ""             # "" | "trimmed" | "keep-base"

    @property
    def n_excluded(self) -> int:
        return len(self.clients) - len(self.kept)


def screen_updates(base, trees: Sequence, weights: Sequence[float],
                   clients: Sequence[int], ledger: TrustLedger,
                   cfg: ScreeningConfig,
                   stats_fn: Callable) -> ScreenReport:
    """Run the finite/norm/direction checks and update the trust EMA.

    ``stats_fn(base, trees, weights) -> (finite, norms, cos)`` supplies
    the per-update delta statistics (the batched engine computes them in
    one jitted call, :func:`repro.federation.engine.screen_stats`).
    Verdicts are recorded into ``ledger`` in cohort order; the low-trust
    exclusion then uses the *post-update* scores, so a client failing
    right now is judged with that failure already priced in.
    """
    finite, norms, cos = stats_fn(base, trees, weights)
    finite = np.asarray(finite, bool)
    norms = np.asarray(norms, np.float64)
    med = float(np.median(norms[finite])) if finite.any() else 0.0
    verdicts: List[str] = []
    for i, n in enumerate(clients):
        if not finite[i]:
            v = NONFINITE
        elif med > 0.0 and norms[i] > cfg.norm_k * med:
            v = NORM
        elif float(cos[i]) < cfg.cos_min:
            v = FLIP
        else:
            v = OK
        ledger.record(n, v == OK)
        verdicts.append(v)
    kept = [i for i, (v, n) in enumerate(zip(verdicts, clients))
            if v == OK and ledger.scores[n] >= cfg.trust_floor]
    for i in range(len(verdicts)):
        if verdicts[i] == OK and i not in kept:
            verdicts[i] = LOW_TRUST
    if tm.enabled():
        for v in verdicts:
            tm.inc("screening.verdicts", 1, verdict=v)
        tm.set_gauge("screening.trust_mean", float(ledger.scores.mean()))
        tm.set_gauge("screening.trust_min", float(ledger.scores.min()))
        tm.set_gauge("screening.below_floor",
                     int((ledger.scores < cfg.trust_floor).sum()))
    return ScreenReport(list(clients), verdicts, kept)


def screen_and_aggregate(base, trees: Sequence, weights: Sequence[float],
                         clients: Sequence[int], ledger: TrustLedger,
                         cfg: ScreeningConfig, mode: str,
                         stats_fn: Callable) -> Tuple[object, ScreenReport]:
    """Screen a cohort, then aggregate the survivors (see module doc).

    Survivor weights are the FedAvg weights scaled by the trust EMA.
    When the screened cohort is smaller than ``min_cohort`` (but the
    whole cohort is larger), the plain mean over so few updates is
    fragile, so the fallback is a coordinate-wise trimmed mean over
    every *finite* update; with zero survivors and no finite updates at
    all the edge simply keeps ``base``.
    """
    report = screen_updates(base, trees, weights, clients, ledger, cfg,
                            stats_fn)
    kept = report.kept
    if len(kept) >= min(cfg.min_cohort, len(trees)):
        wts = [float(weights[i]) * ledger.weight(clients[i]) for i in kept]
        if sum(wts) > 0.0:
            return (agg.aggregate_adapters([trees[i] for i in kept], wts,
                                           mode=mode), report)
    finite_idx = [i for i, v in enumerate(report.verdicts) if v != NONFINITE]
    if not finite_idx:
        report.fallback = "keep-base"
        tm.inc("screening.fallbacks", 1, kind="keep-base")
        return base, report
    report.fallback = "trimmed"
    tm.inc("screening.fallbacks", 1, kind="trimmed")
    return (agg.trimmed_mean([trees[i] for i in finite_idx],
                             trim_frac=cfg.trim_frac), report)
