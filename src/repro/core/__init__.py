"""ELSA core: the paper's contribution as composable JAX modules.

- fingerprint:     behavioral Gaussian fingerprints + symmetric KLD (Eqs. 4-6)
- trust:           prediction-consistency trust scores
- clustering:      latency-feasible trust-weighted spectral clustering (Stages 1-4)
- splitting:       resource-aware dynamic tripartite splits (Eqs. 7-9)
- ssop:            semantic-subspace orthogonal perturbation (Eqs. 17-19)
- sketch:          count-sketch activation compression (Eqs. 20-21)
- split_training:  tripartite split train step with the SS-OP∘sketch channel
- aggregation:     edge FedAvg + cloud coherence/trust fusion (Eqs. 14-16)
- comm_model:      communication volume/latency model (Eqs. 22-24)
"""
