"""Trust- and communication-aware client clustering (ELSA §III.B.1,
Stages 1–4).

Host-side orchestration (numpy/scipy): N is tens-to-hundreds of clients.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np
import scipy.linalg


@dataclasses.dataclass
class ClusterResult:
    groups: Dict[int, List[int]]        # edge k -> client ids (the N_k)
    escalated: List[int]                # clients escalated to cloud-level
    excluded: List[int]                 # out-of-range / untrusted clients
    assignment: Dict[int, Optional[int]]  # client -> edge (None = excluded)
    group_trust: Dict[int, float]       # edge k -> mean trust of its group


def feasible_edges(latency: np.ndarray, tau_max: float) -> List[List[int]]:
    """Stage 0: E_n = {k | tau_nk <= tau_max}.  latency: (N, K)."""
    return [list(np.nonzero(latency[n] <= tau_max)[0])
            for n in range(latency.shape[0])]


def _kmeans(x: np.ndarray, k: int, iters: int = 50, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    centers = x[rng.choice(n, size=k, replace=False)]
    labels = np.zeros(n, np.int64)
    for _ in range(iters):
        d = ((x[:, None, :] - centers[None]) ** 2).sum(-1)
        new = d.argmin(1)
        if (new == labels).all():
            break
        labels = new
        for c in range(k):
            pts = x[labels == c]
            if len(pts):
                centers[c] = pts.mean(0)
    return labels


def spectral_cluster(affinity: np.ndarray, n_clusters: int,
                     seed: int = 0) -> np.ndarray:
    """Normalized spectral clustering (Ng–Jordan–Weiss)."""
    n = affinity.shape[0]
    n_clusters = min(n_clusters, n)
    if n_clusters <= 1 or n <= 2:
        return np.zeros(n, np.int64)
    a = affinity.copy()
    np.fill_diagonal(a, 0.0)
    deg = a.sum(1)
    d_inv_sqrt = 1.0 / np.sqrt(np.maximum(deg, 1e-12))
    lap = np.eye(n) - d_inv_sqrt[:, None] * a * d_inv_sqrt[None, :]
    vals, vecs = scipy.linalg.eigh(lap)
    emb = vecs[:, :n_clusters]
    norms = np.linalg.norm(emb, axis=1, keepdims=True)
    emb = emb / np.maximum(norms, 1e-12)
    return _kmeans(emb, n_clusters, seed=seed)


def affinity_matrix(div: np.ndarray, trust: np.ndarray,
                    gamma: float) -> np.ndarray:
    """Stage 2 affinity: A_{nn'} = w_n w_n' exp(-gamma R(n,n'))."""
    return np.outer(trust, trust) * np.exp(-gamma * div)


def cluster_clients(div: np.ndarray, trust: np.ndarray, latency: np.ndarray,
                    *, tau_max: float = 200.0, gamma: float = 1.0,
                    w_min: float = 0.3, clusters_per_edge: int = 2,
                    seed: int = 0) -> ClusterResult:
    """Full Stage 1–4 pipeline.

    div: (N, N) symmetric KLD matrix; trust: (N,); latency: (N, K) in ms.
    """
    n_clients, n_edges = latency.shape
    feas = feasible_edges(latency, tau_max)
    # normalize gamma to the divergence scale so exp(-gamma R) is informative
    pos = div[div > 0]
    gamma_eff = gamma / max(float(np.median(pos)) if len(pos) else 1.0, 1e-9)
    # trust scores are scale-normalized (repro.core.trust); interpret w_min
    # RELATIVE to the population mean so the threshold is calibration-free
    w_thresh = w_min * max(float(trust.mean()), 1e-9)

    # Stage 1–2: per-edge candidate sets and spectral clustering
    per_edge_groups: Dict[int, List[List[int]]] = {}
    for k in range(n_edges):
        ck = [nn for nn in range(n_clients) if k in feas[nn]]
        if not ck:
            per_edge_groups[k] = []
            continue
        sub = div[np.ix_(ck, ck)]
        aff = affinity_matrix(sub, trust[ck], gamma_eff)
        labels = spectral_cluster(aff, clusters_per_edge, seed=seed)
        per_edge_groups[k] = [
            [ck[i] for i in np.nonzero(labels == c)[0]]
            for c in range(labels.max() + 1)]

    # per edge: keep every sub-cluster whose mean trust clears w_min
    # (low-trust sub-clusters are dropped here; group-level rescue/merge
    # happens in Stages 3-4); if none clears, keep the best-scoring one.
    chosen: Dict[int, List[int]] = {}
    for k, groups in per_edge_groups.items():
        kept: List[int] = []
        best, best_score = [], -np.inf
        for g in groups:
            if not g:
                continue
            mean_trust = trust[g].mean()
            score = mean_trust * np.sqrt(len(g))
            if score > best_score:
                best, best_score = g, score
            if mean_trust >= w_thresh:
                kept.extend(g)
        chosen[k] = kept if kept else best

    # resolve clients claimed by several edges: lowest latency wins
    assignment: Dict[int, Optional[int]] = {nn: None for nn in range(n_clients)}
    for nn in range(n_clients):
        claimants = [k for k, g in chosen.items() if nn in g]
        if claimants:
            assignment[nn] = int(min(claimants, key=lambda k: latency[nn, k]))
    groups = {k: [nn for nn in range(n_clients) if assignment[nn] == k]
              for k in range(n_edges)}

    # Stage 3–4: low-trust clusters merge into nearest high-trust cluster
    # (centroid KLD) or escalate to the cloud.
    group_trust = {k: (float(trust[g].mean()) if g else 0.0)
                   for k, g in groups.items()}
    escalated: List[int] = []
    for k in list(groups):
        g = groups[k]
        if not g or group_trust[k] >= w_thresh:
            continue
        # centroid distance to other groups = mean cross-KLD
        targets = [k2 for k2 in groups
                   if k2 != k and groups[k2] and group_trust[k2] >= w_thresh]
        if targets:
            def cross(k2):
                return float(div[np.ix_(g, groups[k2])].mean())
            k_best = min(targets, key=cross)
            groups[k_best] = groups[k_best] + g
        else:
            escalated.extend(g)
        groups[k] = []
        group_trust[k] = 0.0
    for k in groups:
        if groups[k]:
            group_trust[k] = float(trust[groups[k]].mean())
        for nn in groups[k]:
            assignment[nn] = k
    for nn in escalated:
        assignment[nn] = None

    excluded = [nn for nn in range(n_clients)
                if assignment[nn] is None and nn not in escalated]
    return ClusterResult(groups=groups, escalated=escalated,
                         excluded=excluded, assignment=assignment,
                         group_trust=group_trust)
