"""Trustworthiness scores (ELSA §III.B.1 Step 4).

``w_n^trust = exp(-(1/Q) Σ_j 1/||T_n^(j)||_2  -  mean_n' R(n, n'))``.

The raw paper formula underflows when KLD values are large (hundreds), so
``normalize=True`` (default) rescales the mean-divergence term by the
population mean before exponentiation — a monotone transform that
preserves the ordering the score is used for (down-weighting outliers)
while keeping scores in a numerically useful range.
"""
from __future__ import annotations

import numpy as np


def inverse_confidence(probe_norms: np.ndarray) -> np.ndarray:
    """(N, Q) array of ||T_n^(j)||_2 -> (N,) mean inverse confidence."""
    return (1.0 / np.maximum(probe_norms, 1e-9)).mean(axis=1)


def trust_scores(div_matrix: np.ndarray, probe_norms: np.ndarray,
                 normalize: bool = True) -> np.ndarray:
    """Compute w_n^trust for all clients.

    div_matrix: (N, N) symmetric KLD; probe_norms: (N, Q) embedding norms.
    """
    n = div_matrix.shape[0]
    inv_conf = inverse_confidence(probe_norms)
    off = div_matrix.sum(axis=1) / max(n - 1, 1)         # mean divergence
    if normalize:
        scale = max(float(off.mean()), 1e-9)
        off = off / scale
        inv_conf = inv_conf / max(float(inv_conf.mean()), 1e-9)
    return np.exp(-inv_conf - off)
