"""Edge- and cloud-level aggregation (ELSA §III.B.2, Eqs. 14–16).

Two adapter-aggregation modes (:func:`aggregate_adapters`):

- ``"factor"`` — the historical per-leaf weighted mean.  Averaging LoRA
  factor pairs (A, B) leafwise is *wrong* in weight space: the implied
  update is ``mean(A)·mean(B)``, not ``mean(A·B)``, so per-client
  adapter progress pointing in different factor directions cancels even
  when the weight-space deltas agree (HSplitLoRA, arXiv:2505.02795).
  Kept behind the flag for golden parity with recorded histories.
- ``"product"`` — aggregate in the product/weight-delta space: compute
  each client's per-layer ``ΔW = A·B``, take the weighted mean of the
  ΔW trees, and re-fit the factors to the mean *anchored at the factor
  mean*: ``A ← mean(A_i)`` (optimization continuity — replacing A with
  e.g. the delta's singular vectors every round churns the adapter
  geometry and measurably stalls training) and
  ``B ← mean(B_i) + A⁺ (ΔW_mean − A·mean(B_i))``, i.e. the factor
  mean's residual against the true weight-space mean is folded into B
  through A's pseudo-inverse.  The implied delta equals the projection
  of ``ΔW_mean`` onto col(A), so its error against the true mean is
  *never larger* than factor averaging's (the correction is a
  projection), it is exact for a single client (the correction
  vanishes), and exact whenever clients share A (heterogeneity only in
  B — the residual then lies entirely in col(A)).

Factor pairs are recognized structurally: any dict node holding both
``<t>_a`` and ``<t>_b`` leaves whose ranks contract (``a``'s last axis
== ``b``'s first axis after the shared leading layer-stack axis), which
is exactly how :mod:`repro.models.common` lays LoRA adapters out.
Non-pair leaves (pooler/head/bias) always take the plain weighted mean.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def fedavg(trees: Sequence, weights: Sequence[float]):
    """Weighted average of parameter pytrees."""
    if not trees:
        raise ValueError("fedavg: no trees to aggregate")
    w = np.asarray(weights, np.float64)
    # python-float (weak-typed) weights: full precision without
    # upcasting f32 parameter leaves
    w = [float(x) for x in w / max(w.sum(), 1e-12)]
    def avg(*leaves):
        out = leaves[0] * w[0]
        for wi, leaf in zip(w[1:], leaves[1:]):
            out = out + wi * leaf
        return out
    return jax.tree_util.tree_map(avg, *trees)


# ---------------------------------------------------------------------------
# product-space (weight-delta) adapter aggregation
# ---------------------------------------------------------------------------

def _pair_targets(node) -> List[str]:
    """LoRA factor-pair targets in a dict node: ``t`` for ``t_a``/``t_b``."""
    if not isinstance(node, dict):
        return []
    return sorted(t[:-2] for t in node
                  if t.endswith("_a") and f"{t[:-2]}_b" in node)


def _is_pair_node(node) -> bool:
    return bool(_pair_targets(node))


def pair_delta(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Per-layer weight delta ``ΔW = A·B`` of a layer-stacked factor pair.

    ``a``: (L, ..., r) with the rank axis last; ``b``: (L, r, ...) with
    the rank axis first after the layer axis.  Returns (L, m, k) with
    ``m = prod(a.shape[1:-1])``, ``k = prod(b.shape[2:])`` — the
    flattened per-layer delta matrices.  The LoRA ``alpha/r`` scale is a
    shared constant and commutes with averaging, so deltas stay
    unscaled here.
    """
    f = lambda ai, bi: (ai.reshape(-1, ai.shape[-1])
                        @ bi.reshape(bi.shape[0], -1))
    return jax.vmap(f)(a, b)


def refactor_delta(dw: jnp.ndarray, a_mean: jnp.ndarray,
                   b_mean: jnp.ndarray, eps: float = 1e-8):
    """Re-fit a factor pair to the mean delta, anchored at the factor mean.

    Per layer: ``A ← Ā`` and ``B ← B̄ + Ā⁺ (ΔW − Ā B̄)`` with
    ``Ā⁺ = (ĀᵀĀ + εI)⁻¹ Āᵀ`` (an r×r ridge solve — r is the LoRA
    rank, so this is tiny).  The correction adds exactly the part of
    the factor-averaging error that lies in col(Ā); anything orthogonal
    to the adapter's input subspace is unreachable at rank r without
    replacing Ā, which destroys optimization continuity (measured: SVD
    re-factorization stalls split-LM training even at n=1).
    """
    r = a_mean.shape[-1]

    def f(a, b, d):
        am = a.reshape(-1, r)
        bm = b.reshape(r, -1)
        res = d - am @ bm
        gram = am.T @ am + eps * jnp.eye(r, dtype=am.dtype)
        return bm + jnp.linalg.solve(gram, am.T @ res)

    bn = jax.vmap(f)(a_mean, b_mean, dw)
    return a_mean, bn.reshape(b_mean.shape).astype(b_mean.dtype)


def tree_to_deltas(tree):
    """Replace every factor pair with its ``<t>_dw`` product; other
    leaves pass through.  The returned delta-tree is what edge→cloud
    fusion carries in product mode."""
    if isinstance(tree, dict):
        if _is_pair_node(tree):
            out = {k: v for k, v in tree.items()
                   if k[:-2] not in _pair_targets(tree)}
            for t in _pair_targets(tree):
                out[f"{t}_dw"] = pair_delta(tree[f"{t}_a"], tree[f"{t}_b"])
            return out
        return {k: tree_to_deltas(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(tree_to_deltas(v) for v in tree)
    return tree


def deltas_to_tree(deltas, fmean):
    """Re-fit the factor-mean tree ``fmean`` to a delta-tree: every
    factor pair gets the anchored pinv correction; non-pair leaves are
    taken from ``deltas`` (they were plain-averaged there)."""
    if isinstance(fmean, dict):
        if _is_pair_node(fmean):
            out = {k: deltas[k] for k in fmean
                   if k[:-2] not in _pair_targets(fmean)}
            for t in _pair_targets(fmean):
                a, b = refactor_delta(deltas[f"{t}_dw"],
                                      fmean[f"{t}_a"],
                                      fmean[f"{t}_b"])
                out[f"{t}_a"], out[f"{t}_b"] = a, b
            return out
        return {k: deltas_to_tree(deltas[k], v)
                for k, v in fmean.items()}
    if isinstance(fmean, (list, tuple)):
        return type(fmean)(deltas_to_tree(d, v)
                           for d, v in zip(deltas, fmean))
    return deltas


def product_fedavg(trees: Sequence, weights: Sequence[float]):
    """Weighted mean in the weight-delta space, re-fit to rank-r factors
    anchored at the factor mean (see module docstring)."""
    if len(trees) == 1:
        return trees[0]        # exact: nothing to correct, zero churn
    fmean = fedavg(trees, weights)
    deltas = fedavg([tree_to_deltas(t) for t in trees], weights)
    return deltas_to_tree(deltas, fmean)


def aggregate_adapters(trees: Sequence, weights: Sequence[float],
                       mode: str = "factor"):
    """Mode dispatch: ``"factor"`` (legacy leafwise mean, bit-identical
    to :func:`fedavg`) or ``"product"`` (weight-delta mean, re-fit to
    factors by the anchored pinv correction — see module docstring)."""
    if mode == "factor":
        return fedavg(trees, weights)
    if mode == "product":
        return product_fedavg(trees, weights)
    raise ValueError(f"unknown aggregation mode {mode!r}")


def trimmed_mean(trees: Sequence, trim_frac: float = 0.25):
    """Coordinate-wise trimmed mean across client trees.

    Per coordinate, the ``int(trim_frac * n)`` smallest and largest
    values are discarded and the rest averaged — the classic
    Byzantine-robust estimator (Yin et al. 2018), used by the screening
    stage as its small-cohort fallback.  Callers must pass finite trees
    (NaNs sort to the top and would survive a one-sided trim).
    """
    n = len(trees)
    if n == 0:
        raise ValueError("trimmed_mean: no trees to aggregate")
    if not 0.0 <= trim_frac < 0.5:
        raise ValueError(f"trim_frac must be in [0, 0.5), got {trim_frac}")
    k = min(int(trim_frac * n), (n - 1) // 2)

    def f(*leaves):
        x = jnp.sort(jnp.stack(leaves), axis=0)
        return x[k:n - k].mean(axis=0).astype(leaves[0].dtype)

    return jax.tree_util.tree_map(f, *trees)


def mix_adapters(theta, update, w: float, mode: str = "factor"):
    """Asynchronous edge fold ``θ ← (1-w)·θ + w·update`` in the chosen
    space (the async scheduler's staleness-weighted mixing)."""
    if mode == "product":
        return product_fedavg([theta, update], [1.0 - w, w])
    return jax.tree_util.tree_map(lambda a, b: (1.0 - w) * a + w * b,
                                  theta, update)


def edge_weight(mean_pairwise_kld: float, mean_trust: float) -> float:
    """Eq. 14: alpha_k = (1 / (1 + R̄_k)) * w̄_k^trust."""
    return (1.0 / (1.0 + mean_pairwise_kld)) * mean_trust


def mean_pairwise_kld(div: np.ndarray, members: List[int]) -> float:
    """R̄_k over a client group (Eq. 14's coherence term)."""
    if len(members) < 2:
        return 0.0
    sub = div[np.ix_(members, members)]
    n = len(members)
    return float(sub.sum() / (n * (n - 1)))


def cloud_aggregate(edge_params: Dict[int, object],
                    alphas: Dict[int, float], mode: str = "factor"):
    """Eq. 15: theta_g = sum_k alpha~_k theta_{g,k}.

    In ``"product"`` mode the fusion is carried in delta-tree space:
    each edge model's factor pairs are converted to weight deltas, the
    coherence/trust-weighted mean is taken over the delta-trees, and
    the result is re-factored to rank r exactly once — so cloud fusion
    never averages factor pairs leafwise.
    """
    ks = sorted(edge_params)
    weights = [max(alphas[k], 0.0) for k in ks]
    return aggregate_adapters([edge_params[k] for k in ks], weights,
                              mode=mode)


def _sq_norm(theta_new, theta_old) -> float:
    return sum(
        float(jnp.sum((a - b).astype(
            jnp.promote_types(a.dtype, jnp.float32)) ** 2))
        for a, b in zip(jax.tree_util.tree_leaves(theta_new),
                        jax.tree_util.tree_leaves(theta_old)))


def converged(theta_new, theta_old, xi: float) -> bool:
    """Eq. 16: ||theta_g - theta_{g-1}||_2 <= xi."""
    return float(np.sqrt(_sq_norm(theta_new, theta_old))) <= xi


def global_delta(theta_new, theta_old) -> float:
    return float(np.sqrt(_sq_norm(theta_new, theta_old)))
