"""Edge- and cloud-level aggregation (ELSA §III.B.2, Eqs. 14–16)."""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def fedavg(trees: Sequence, weights: Sequence[float]):
    """Weighted average of parameter pytrees."""
    if not trees:
        raise ValueError("fedavg: no trees to aggregate")
    w = np.asarray(weights, np.float64)
    # python-float (weak-typed) weights: full precision without
    # upcasting f32 parameter leaves
    w = [float(x) for x in w / max(w.sum(), 1e-12)]
    def avg(*leaves):
        out = leaves[0] * w[0]
        for wi, leaf in zip(w[1:], leaves[1:]):
            out = out + wi * leaf
        return out
    return jax.tree_util.tree_map(avg, *trees)


def edge_weight(mean_pairwise_kld: float, mean_trust: float) -> float:
    """Eq. 14: alpha_k = (1 / (1 + R̄_k)) * w̄_k^trust."""
    return (1.0 / (1.0 + mean_pairwise_kld)) * mean_trust


def mean_pairwise_kld(div: np.ndarray, members: List[int]) -> float:
    """R̄_k over a client group (Eq. 14's coherence term)."""
    if len(members) < 2:
        return 0.0
    sub = div[np.ix_(members, members)]
    n = len(members)
    return float(sub.sum() / (n * (n - 1)))


def cloud_aggregate(edge_params: Dict[int, object],
                    alphas: Dict[int, float]):
    """Eq. 15: theta_g = sum_k alpha~_k theta_{g,k}."""
    ks = sorted(edge_params)
    weights = [max(alphas[k], 0.0) for k in ks]
    return fedavg([edge_params[k] for k in ks], weights)


def _sq_norm(theta_new, theta_old) -> float:
    return sum(
        float(jnp.sum((a - b).astype(
            jnp.promote_types(a.dtype, jnp.float32)) ** 2))
        for a, b in zip(jax.tree_util.tree_leaves(theta_new),
                        jax.tree_util.tree_leaves(theta_old)))


def converged(theta_new, theta_old, xi: float) -> bool:
    """Eq. 16: ||theta_g - theta_{g-1}||_2 <= xi."""
    return float(np.sqrt(_sq_norm(theta_new, theta_old))) <= xi


def global_delta(theta_new, theta_old) -> float:
    return float(np.sqrt(_sq_norm(theta_new, theta_old)))
