"""Behavioral fingerprints (ELSA §III.B.1, Eqs. 4–6).

Each client's behavior on the public probe set is summarized as a
multivariate Gaussian over its pooled hidden representations
(``[CLS]`` for encoders; pooled final hidden state for decoder-only /
SSM architectures — see DESIGN.md §8).  Pairwise behavioral discrepancy
is the symmetrized KL divergence between those Gaussians.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class Fingerprint(NamedTuple):
    mu: jnp.ndarray      # (D,)
    sigma: jnp.ndarray   # (D, D)


def fingerprint(embeddings: jnp.ndarray, ridge: float = 1e-3) -> Fingerprint:
    """Eq. 4: R_n = N(mu_n, Sigma_n) from probe embeddings (Q, D).

    A ridge term keeps Sigma positive-definite when Q < D (the paper's
    Q=100 << D=768 regime necessarily yields a rank-deficient MLE).
    """
    acc = jnp.promote_types(embeddings.dtype, jnp.float32)
    embeddings = embeddings.astype(acc)
    q, d = embeddings.shape
    mu = embeddings.mean(0)
    centered = embeddings - mu
    sigma = (centered.T @ centered) / q + ridge * jnp.eye(d, dtype=acc)
    return Fingerprint(mu, sigma)


def kl_gaussian(a: Fingerprint, b: Fingerprint) -> jnp.ndarray:
    """Eq. 6: closed-form KL(N_a || N_b), via Cholesky for stability."""
    d = a.mu.shape[0]
    lb = jnp.linalg.cholesky(b.sigma)
    la = jnp.linalg.cholesky(a.sigma)
    # tr(Sigma_b^-1 Sigma_a) = ||Lb^-1 La||_F^2
    m = jax.scipy.linalg.solve_triangular(lb, la, lower=True)
    tr = jnp.sum(m * m)
    diff = b.mu - a.mu
    y = jax.scipy.linalg.solve_triangular(lb, diff, lower=True)
    maha = jnp.sum(y * y)
    logdet = 2.0 * (jnp.sum(jnp.log(jnp.diagonal(lb)))
                    - jnp.sum(jnp.log(jnp.diagonal(la))))
    return 0.5 * (tr - d + logdet + maha)


def sym_kl(a: Fingerprint, b: Fingerprint) -> jnp.ndarray:
    """Eq. 5: R(n, n') = KL(a||b) + KL(b||a)."""
    return kl_gaussian(a, b) + kl_gaussian(b, a)


def divergence_matrix(fps: Sequence[Fingerprint]) -> np.ndarray:
    """Dense (N, N) symmetric KLD matrix (host-side; N is small)."""
    n = len(fps)
    out = np.zeros((n, n), np.float64)
    for i in range(n):
        for j in range(i + 1, n):
            v = float(sym_kl(fps[i], fps[j]))
            out[i, j] = out[j, i] = v
    return out


def pooled_embedding(hidden: jnp.ndarray, family: str) -> jnp.ndarray:
    """Task-agnostic per-input profile: [CLS] for encoders, mean-pool
    otherwise (DESIGN.md §8)."""
    if family == "encoder":
        return hidden[:, 0, :]
    return hidden.mean(axis=1)
