"""Tripartite split training (ELSA §III.B.2–3), model-agnostic.

The model stack is cut at (p, p+q): Part 1 (embedding + blocks[:p],
client), Part 2 (blocks[p:p+q], edge), Part 3 (blocks[p+q:] + head,
client).  Activations crossing each cut pass through the ELSA channel
(SS-OP -> count-sketch -> median-decode -> SS-OPᵀ).  The channel is a
composition of linear maps, so JAX autodiff's VJP is exactly the paper's
symmetric backward path (gradients compressed the same way, with Q_nᵀ
restoring rotation exactly).

Every entry point takes a :class:`~repro.models.split_api.SplitModel`
(or, as a back-compat shim, an ``ArchConfig``, which is adapted through
the split-model registry) — split training itself never names an
architecture.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.sketch import SketchPlan, compress, decompress
from repro.core.ssop import SSOP, apply_ssop, apply_ssop_inverse
from repro.models.split_api import as_split_model


class Channel(NamedTuple):
    """The client<->edge activation channel."""
    ssop: Optional[SSOP]
    plan: Optional[SketchPlan]

    def __call__(self, h: jnp.ndarray) -> jnp.ndarray:
        if self.ssop is not None:
            h = apply_ssop(h, self.ssop)
        if self.plan is not None:
            h = decompress(compress(h, self.plan), self.plan)
        if self.ssop is not None:
            h = apply_ssop_inverse(h, self.ssop)
        return h

    def transmit(self, h: jnp.ndarray) -> jnp.ndarray:
        """What actually crosses the network (privacy-attack surface)."""
        if self.ssop is not None:
            h = apply_ssop(h, self.ssop)
        if self.plan is not None:
            h = compress(h, self.plan)
        return h


IDENTITY_CHANNEL = Channel(None, None)


@dataclasses.dataclass(frozen=True)
class Split:
    p: int
    q: int
    o: int


def split_forward(model, frozen, lora, tokens, split: Split,
                  channel: Channel = IDENTITY_CHANNEL,
                  mask_valid=None):
    """Split forward pass; returns (repr, logits, h_up, h_down).

    ``model`` is a :class:`~repro.models.split_api.SplitModel` (an
    ``ArchConfig`` is adapted via the registry).
    """
    m = as_split_model(model)
    x = m.embed(frozen, tokens)
    # Part 1 (client)
    h_up = m.run_blocks(frozen, lora, x, 0, split.p, mask_valid)
    h_up_t = channel(h_up)
    # Part 2 (edge)
    h_down = m.run_blocks(frozen, lora, h_up_t,
                          split.p, split.p + split.q, mask_valid)
    h_down_t = channel(h_down)
    # Part 3 (client)
    x = m.run_blocks(frozen, lora, h_down_t,
                     split.p + split.q, m.num_blocks, mask_valid)
    repr_, logits = m.head(frozen, lora, x)
    return repr_, logits, h_up, h_down


def split_loss(model, frozen, lora, batch, split: Split,
               channel: Channel = IDENTITY_CHANNEL):
    m = as_split_model(model)
    _, logits, _, _ = split_forward(m, frozen, lora, batch["tokens"],
                                    split, channel,
                                    batch.get("mask_valid"))
    return jnp.mean(m.per_example_loss(logits, batch))


def weighted_split_loss(model, frozen, lora, batch, split: Split,
                        channel: Channel = IDENTITY_CHANNEL):
    """``split_loss`` with per-example weights: Σ w_i ℓ_i / Σ w_i.

    The batched federation engine pads ragged epoch-tail batches up to a
    fixed batch size with zero-weight rows so every client shares one
    compiled shape; zero weights zero the padded rows' loss AND gradient
    contributions exactly, so a fully-weighted batch reproduces
    ``split_loss`` bit-for-bit (examples are independent across the batch
    axis — attention, layernorm, and the SS-OP∘sketch channel all act
    per example).  An all-zero weight vector (a padded *client* row from
    cohort bucket padding) yields exactly zero loss and gradients
    instead of 0/0.
    """
    m = as_split_model(model)
    _, logits, _, _ = split_forward(m, frozen, lora, batch["tokens"],
                                    split, channel,
                                    batch.get("mask_valid"))
    per = m.per_example_loss(logits, batch)
    w = batch["weights"].astype(per.dtype)
    s = jnp.sum(w)
    return jnp.sum(per * w) / jnp.where(s > 0, s, jnp.ones_like(s))


def split_train_step(model, split: Split, channel: Channel, optimizer, *,
                     donate: bool = False):
    """Build a compiled (frozen, lora, opt_state, batch) -> ... step.

    Gradients flow Part 3 -> channelᵀ -> Part 2 -> channelᵀ -> Part 1
    automatically (the channel is linear).  The step is jit-compiled so
    local training dispatches one executable per step instead of tracing
    op-by-op.  ``donate=True`` additionally donates the lora/opt_state
    buffers (in-place update on accelerators; skipped on CPU where XLA
    has no donation) — callers must then not reuse the input arrays.
    For whole-round compilation across a client population see
    :mod:`repro.federation.engine`.
    """
    m = as_split_model(model)

    def step(frozen, lora, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda lp: split_loss(m, frozen, lp, batch, split, channel)
        )(lora)
        lora_new, opt_state = optimizer.update(lora, grads, opt_state)
        return lora_new, opt_state, loss

    donate_argnums = (1, 2) if donate and jax.default_backend() != "cpu" \
        else ()
    return jax.jit(step, donate_argnums=donate_argnums)
