"""Communication volume / latency model (ELSA §III.B.4, Eqs. 22–24).

:func:`comm_config_from` derives a :class:`CommConfig` from the *actual*
artifacts of a federation — the model config, the count-sketch plan, and
the LoRA parameter tree — instead of hand-typed constants, so the byte
counts used by benchmarks and the event-driven runtime track whatever
shapes the run really transmits.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class CommConfig:
    t_rounds: int            # t: client-edge rounds per global aggregation
    bytes_per_param: float   # zeta (4 for fp32)
    seq_len: int             # mu: tokens per input
    d_hidden: int            # D^hidden
    rho: float               # sketch compression ratio
    lora_bytes: int          # |theta^LoRA| per edge->cloud upload


def lora_tree_bytes(lora, bytes_per_param: Optional[float] = None) -> int:
    """Serialized size of a LoRA pytree: array leaves use their own dtype;
    :class:`~repro.models.params.Spec` leaves use ``bytes_per_param``."""
    import jax.tree_util as jtu

    from repro.models.params import is_spec

    total = 0
    for leaf in jtu.tree_leaves(lora, is_leaf=is_spec):
        if is_spec(leaf):
            total += int(np.prod(leaf.shape) * (bytes_per_param or 4.0))
        else:
            total += int(leaf.size) * np.dtype(leaf.dtype).itemsize
    return total


def comm_config_from(cfg, fed, plan=None, *, lora=None,
                     seq_len: Optional[int] = None,
                     num_classes: Optional[int] = None) -> CommConfig:
    """Derive the Eq. 22–24 constants from real run artifacts.

    - ``d_hidden`` = the model's hidden width (what actually crosses the
      split boundary before sketching);
    - ``rho`` = the *effective* compression ratio of ``plan``
      (``D / (Y·Z)``), 1.0 when no sketch plan is used;
    - ``bytes_per_param`` from the config's activation dtype (activations
      are what Eq. 22's zeta multiplies);
    - ``lora_bytes`` from the actual LoRA tree when given, else from the
      model's LoRA parameter specs at the param dtype;
    - ``seq_len``/``t_rounds`` from the federation config (``fed.seq_len``
      may be overridden per task via ``seq_len=``).

    ``fed`` is any object with ``t_rounds``/``seq_len``/``num_classes``
    attributes (a :class:`~repro.federation.simulation.FedConfig`).

    Model shapes come from the :class:`~repro.models.split_api.SplitModel`
    adapter of ``cfg`` — the LoRA upload is priced off ``lora_specs`` and
    the boundary width off ``activation_shape``, so any registered
    architecture (encoder or causal LM) gets correct Eq. 22–24 constants.
    """
    from repro.models.split_api import split_model_for

    model = split_model_for(cfg)
    zeta = float(np.dtype(cfg.activation_dtype).itemsize)
    rho = float(plan.rho) if plan is not None else 1.0
    if lora is None:
        lora = model.lora_specs(num_classes
                                or getattr(fed, "num_classes", 2))
    lb = lora_tree_bytes(lora, np.dtype(cfg.param_dtype).itemsize)
    return CommConfig(
        t_rounds=int(fed.t_rounds), bytes_per_param=zeta,
        seq_len=int(seq_len if seq_len is not None
                    else getattr(fed, "seq_len", cfg.max_position_embeddings)),
        d_hidden=int(model.activation_shape(1, 1)[-1]), rho=rho,
        lora_bytes=lb)


def round_volume_bytes(cc: CommConfig, batch_sizes_per_edge: Dict[int, List[float]],
                       n_edges: int) -> float:
    """Eq. 22: C_g = 2 t ζ μ D / ρ * Σ_k Σ_n B_n  +  K |θ_LoRA|."""
    total_b = sum(sum(bs) for bs in batch_sizes_per_edge.values())
    activ = 2.0 * cc.t_rounds * cc.bytes_per_param * cc.seq_len \
        * cc.d_hidden / cc.rho * total_b
    return activ + n_edges * cc.lora_bytes


def client_comm_time(cc: CommConfig, batch_size: float,
                     bandwidth_bytes_per_s: float) -> float:
    """Eq. 23: T_{g,n} = 2 t B_n μ ζ D / ρ / B_n^bw."""
    vol = 2.0 * cc.t_rounds * batch_size * cc.seq_len \
        * cc.bytes_per_param * cc.d_hidden / cc.rho
    return vol / max(bandwidth_bytes_per_s, 1e-9)


def total_comm_time(cc: CommConfig, batch_sizes: Sequence[float],
                    bandwidths: Sequence[float], n_global_rounds: int
                    ) -> float:
    """Eq. 24: T ≈ G * max_n T_{g,n} (the straggler bound)."""
    per_client = [client_comm_time(cc, b, bw)
                  for b, bw in zip(batch_sizes, bandwidths)]
    return n_global_rounds * max(per_client)
