"""Communication volume / latency model (ELSA §III.B.4, Eqs. 22–24)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence


@dataclasses.dataclass(frozen=True)
class CommConfig:
    t_rounds: int            # t: client-edge rounds per global aggregation
    bytes_per_param: float   # zeta (4 for fp32)
    seq_len: int             # mu: tokens per input
    d_hidden: int            # D^hidden
    rho: float               # sketch compression ratio
    lora_bytes: int          # |theta^LoRA| per edge->cloud upload


def round_volume_bytes(cc: CommConfig, batch_sizes_per_edge: Dict[int, List[float]],
                       n_edges: int) -> float:
    """Eq. 22: C_g = 2 t ζ μ D / ρ * Σ_k Σ_n B_n  +  K |θ_LoRA|."""
    total_b = sum(sum(bs) for bs in batch_sizes_per_edge.values())
    activ = 2.0 * cc.t_rounds * cc.bytes_per_param * cc.seq_len \
        * cc.d_hidden / cc.rho * total_b
    return activ + n_edges * cc.lora_bytes


def client_comm_time(cc: CommConfig, batch_size: float,
                     bandwidth_bytes_per_s: float) -> float:
    """Eq. 23: T_{g,n} = 2 t B_n μ ζ D / ρ / B_n^bw."""
    vol = 2.0 * cc.t_rounds * batch_size * cc.seq_len \
        * cc.bytes_per_param * cc.d_hidden / cc.rho
    return vol / max(bandwidth_bytes_per_s, 1e-9)


def total_comm_time(cc: CommConfig, batch_sizes: Sequence[float],
                    bandwidths: Sequence[float], n_global_rounds: int
                    ) -> float:
    """Eq. 24: T ≈ G * max_n T_{g,n} (the straggler bound)."""
    per_client = [client_comm_time(cc, b, bw)
                  for b, bw in zip(batch_sizes, bandwidths)]
    return n_global_rounds * max(per_client)
