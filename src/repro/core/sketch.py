"""Count-sketch compression of hidden activations (ELSA §III.B.3,
Eqs. 20–21): Y pairwise-independent (bucket, sign) hash rows, Z buckets,
median-of-Y decoding.  Compression ratio rho = D / (Y*Z).

TPU adaptation (DESIGN.md §3): the hash scatter is re-expressed as a
signed-selection matmul — ``sketch[y] = H @ S_y`` with
``S_y ∈ {-1,0,+1}^{D×Z}`` — so compression runs on the MXU; decompression
is the transposed gather + median.  Both forms are provided (scatter for
CPU-exactness tests, matmul for the compiled path / Pallas kernel) and are
bit-identical in fp32.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class SketchPlan(NamedTuple):
    bucket: jnp.ndarray    # (Y, D) int32 in [0, Z)
    sign: jnp.ndarray      # (Y, D) float32 in {-1, +1}
    z: int
    # Dense signed-selection tensor S (Y, D, Z); precomputed by
    # ``make_plan`` so compress/decompress (and their VJPs) never rebuild
    # the D×Z one-hot per call.  ``None`` for hand-built plans.
    selection: Optional[jnp.ndarray] = None

    @property
    def y(self) -> int:
        return self.bucket.shape[0]

    @property
    def d(self) -> int:
        return self.bucket.shape[1]

    @property
    def rho(self) -> float:
        """Compression ratio D / (Y Z)."""
        return self.d / (self.y * self.z)


def _selection_from(bucket: jnp.ndarray, sign: jnp.ndarray,
                    z: int) -> jnp.ndarray:
    oh = jax.nn.one_hot(bucket, z, dtype=jnp.float32)           # (Y, D, Z)
    return oh * sign[..., None]


# typing.NamedTuple forbids overriding _replace in the class body, so the
# sync-on-replace hook has to be patched onto the class after creation.
_namedtuple_replace = SketchPlan._replace


def _synced_replace(self, **kw):
    """``_replace`` that keeps the cached selection tensor in sync when
    the hash fields change (e.g. tests overriding ``bucket``)."""
    new = _namedtuple_replace(self, **kw)
    if (({"bucket", "sign", "z"} & kw.keys()) and "selection" not in kw
            and self.selection is not None):
        new = _namedtuple_replace(
            new, selection=_selection_from(new.bucket, new.sign, new.z))
    return new


SketchPlan._replace = _synced_replace
# Python 3.13+ copy.replace() dispatches through __replace__, which
# namedtuple binds at class creation — patch it too so it can't bypass
# the selection sync.
SketchPlan.__replace__ = _synced_replace


def make_plan(d: int, y: int, z: int, seed: int = 0) -> SketchPlan:
    rng = np.random.default_rng(seed)
    bucket = rng.integers(0, z, size=(y, d), dtype=np.int32)
    sign = rng.choice(np.array([-1.0, 1.0], np.float32), size=(y, d))
    bucket, sign = jnp.asarray(bucket), jnp.asarray(sign)
    return SketchPlan(bucket, sign, z, _selection_from(bucket, sign, z))


def selection_matrices(plan: SketchPlan) -> jnp.ndarray:
    """Dense signed-selection tensor S (Y, D, Z) for the MXU formulation.

    Returns the tensor cached on the plan when present (``make_plan``
    precomputes it); falls back to building it for hand-rolled plans.
    """
    if plan.selection is not None:
        return plan.selection
    return _selection_from(plan.bucket, plan.sign, plan.z)


def compress(h: jnp.ndarray, plan: SketchPlan, *, via_matmul: bool = True,
             use_kernel: bool = False) -> jnp.ndarray:
    """Eq. 20: h (..., D) -> sketch (..., Y, Z)."""
    if use_kernel:
        from repro.kernels.count_sketch import ops as kops
        return kops.sketch_compress(h, plan)
    hf = h.astype(jnp.promote_types(h.dtype, jnp.float32))
    if via_matmul:
        s = selection_matrices(plan)                    # (Y, D, Z) cached
        return jnp.einsum("...d,ydz->...yz", hf, s).astype(h.dtype)
    # scatter-add reference (per hash row)
    def one_row(yy):
        contrib = jnp.moveaxis(hf * plan.sign[yy], -1, 0)    # (D, ...)
        return jnp.moveaxis(
            jax.ops.segment_sum(contrib, plan.bucket[yy],
                                num_segments=plan.z), 0, -1)  # (..., Z)
    rows = [one_row(yy) for yy in range(plan.y)]
    return jnp.stack(rows, axis=-2).astype(h.dtype)


def decompress(u: jnp.ndarray, plan: SketchPlan, *,
               use_kernel: bool = False) -> jnp.ndarray:
    """Eq. 21: sketch (..., Y, Z) -> estimate (..., D) via median of Y."""
    if use_kernel:
        from repro.kernels.count_sketch import ops as kops
        return kops.sketch_decompress(u, plan)
    uf = u.astype(jnp.promote_types(u.dtype, jnp.float32))
    if plan.selection is not None:
        # transposed selection matmul: est[..., y, d] = Σ_z u[..., y, z] ·
        # S[y, d, z].  Exactly one non-zero per (y, d) row, so this is
        # bit-identical to the gather below (adding exact fp32 zeros).
        est = jnp.einsum("...yz,ydz->...yd", uf, plan.selection)
        return _median(est, axis=-2).astype(u.dtype)
    # gather: est[y, d] = sign[y, d] * u[y, bucket[y, d]]
    ests = []
    for yy in range(plan.y):
        ests.append(jnp.take(uf[..., yy, :], plan.bucket[yy], axis=-1)
                    * plan.sign[yy])
    est = jnp.stack(ests, axis=-2)                      # (..., Y, D)
    return _median(est, axis=-2).astype(u.dtype)


def _median(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Median via an elementwise compare-exchange network.

    Y (the number of hash rows) is small (3–8), so an O(Y^2) min/max
    bubble network is cheap, fully differentiable, and avoids
    ``jnp.sort``/gather (whose VJP is broken in this jaxlib build).
    """
    rows = [jax.lax.index_in_dim(x, i, axis, keepdims=False)
            for i in range(x.shape[axis])]
    n = len(rows)
    for i in range(n):
        for j in range(n - 1 - i):
            lo = jnp.minimum(rows[j], rows[j + 1])
            hi = jnp.maximum(rows[j], rows[j + 1])
            rows[j], rows[j + 1] = lo, hi
    if n % 2:
        return rows[(n - 1) // 2]
    return 0.5 * (rows[n // 2 - 1] + rows[n // 2])


def channel(h: jnp.ndarray, plan: SketchPlan, **kw) -> jnp.ndarray:
    """compress -> decompress round trip (the lossy channel)."""
    return decompress(compress(h, plan, **kw), plan, **{
        k: v for k, v in kw.items() if k == "use_kernel"})
