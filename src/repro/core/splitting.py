"""Resource-aware dynamic model splitting (ELSA §III.B.2, Eqs. 7–9).

Partitions an M-block model into (p_n, q_n, o_fix): Part 1 (client),
Part 2 (edge), Part 3 (client, fixed depth for label privacy).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class SplitPolicy:
    num_blocks: int          # M
    o_fix: int = 2           # output segment depth (label privacy)
    p_min: int = 1           # minimum client-side encoder depth (privacy)
    p_max: int = 6           # empirically determined (paper Fig. 6b)
    lambda1: float = 0.5     # compute weight in the preference score
    lambda2: float = 0.5     # bandwidth weight

    def __post_init__(self):
        assert self.p_max + self.o_fix < self.num_blocks, \
            "p_max + o_fix must leave at least one block for the edge"
        assert abs(self.lambda1 + self.lambda2 - 1.0) < 1e-9
        if self.p_max < self.p_min or self.p_min < 1:
            # a p_max below p_min silently yields splits like
            # Split(p=-1, ...), whose negative block indices wrap around
            # and run the LAST layer as Part 1/2 — training then runs a
            # scrambled deeper network than evaluation (the discrepancy
            # behind chance-level accuracy on too-shallow configs)
            raise ValueError(
                f"model too shallow to split: need num_blocks >= "
                f"p_min + 1 + o_fix = {self.p_min + 1 + self.o_fix} "
                f"(got M={self.num_blocks}, p range "
                f"[{self.p_min}, {self.p_max}], o={self.o_fix})")


def offload_score(h_n: float, h_max: float, b_n: float, b_max: float,
                  policy: SplitPolicy) -> float:
    """Eq. 7: G_n = λ1 (1 - H_n/H_max) + λ2 B_n/B_max  ∈ [0, 1]."""
    return (policy.lambda1 * (1.0 - h_n / max(h_max, 1e-9))
            + policy.lambda2 * (b_n / max(b_max, 1e-9)))


def split_for_client(h_n: float, b_n: float, h_max: float, b_max: float,
                     policy: SplitPolicy) -> Tuple[int, int, int]:
    """Eqs. 8–9: (p_n, q_n, o_fix).  High G_n (weak compute or strong
    uplink) -> small p_n (offload more)."""
    g = offload_score(h_n, h_max, b_n, b_max, policy)
    p = policy.p_max - math.floor(g * (policy.p_max - policy.p_min))
    p = max(policy.p_min, min(policy.p_max, p))
    q = policy.num_blocks - policy.o_fix - p
    return p, q, policy.o_fix


def splits_for_population(capacities: Sequence[float],
                          bandwidths: Sequence[float],
                          policy: SplitPolicy):
    h_max = max(capacities)
    b_max = max(bandwidths)
    return [split_for_client(h, b, h_max, b_max, policy)
            for h, b in zip(capacities, bandwidths)]
