"""Synthetic non-IID text corpora (classification and causal-LM tasks).

Public NLP datasets are unavailable offline; we generate class-conditional
token sequences (each class has a distinct unigram distribution over a
vocab segment, plus shared background tokens) so models genuinely learn the
task, and reproduce the paper's heterogeneity controls:

- label skew: Dirichlet(alpha) class proportions per client (§IV.A),
- quantity skew: |D_n| ∝ chi_n = (n+1)/Omega_k (§IV.A),
- unreliable clients: label poisoning on a chosen subset (§IV.A).

The same corpora serve two tasks, matching the two ``SplitModel`` task
kinds (:mod:`repro.models.split_api`):

- ``task_kind="classification"`` (encoders): predict the class label;
  unreliable clients get a fraction of labels randomly flipped;
- ``task_kind="causal-lm"`` (decoder-only LMs): next-token prediction —
  the class-conditional unigram structure is what makes the text
  learnable; unreliable clients get a fraction of their *sequences*
  scrambled to uniform-random tokens (labels never enter the LM loss,
  so label flips would be invisible there).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticTaskConfig:
    vocab_size: int = 1024
    num_classes: int = 4
    seq_len: int = 32
    class_sharpness: float = 4.0   # how peaked each class's distribution is
    background_frac: float = 0.5   # fraction of positions drawn iid uniform
    cls_token: int = -1            # >= 0: pin this token at position 0 (a
                                   # [CLS] convention — the classification
                                   # head reads position 0, so a constant
                                   # token there makes the readout position
                                   # carry attention-mixed sequence signal
                                   # instead of a random token's embedding)
    seed: int = 0


@dataclasses.dataclass
class ClientData:
    tokens: np.ndarray             # (n, S) int32
    labels: np.ndarray             # (n,) int32
    poisoned: bool = False


def make_task(cfg: SyntheticTaskConfig):
    """Returns class-conditional unigram distributions (C, V)."""
    rng = np.random.default_rng(cfg.seed)
    logits = rng.normal(0.0, 1.0, (cfg.num_classes, cfg.vocab_size))
    # make classes separable: boost a class-specific segment
    seg = cfg.vocab_size // cfg.num_classes
    for c in range(cfg.num_classes):
        logits[c, c * seg:(c + 1) * seg] += cfg.class_sharpness
    p = np.exp(logits - logits.max(1, keepdims=True))
    return p / p.sum(1, keepdims=True)


def sample_examples(cfg: SyntheticTaskConfig, class_p: np.ndarray,
                    labels: np.ndarray, rng) -> np.ndarray:
    """Sample token sequences for given labels."""
    n = len(labels)
    out = np.empty((n, cfg.seq_len), np.int32)
    n_bg = int(cfg.seq_len * cfg.background_frac)
    for i, c in enumerate(labels):
        sig = rng.choice(cfg.vocab_size, size=cfg.seq_len - n_bg,
                         p=class_p[c])
        bg = rng.integers(0, cfg.vocab_size, size=n_bg)
        seq = np.concatenate([sig, bg])
        rng.shuffle(seq)
        out[i] = seq
    if cfg.cls_token >= 0:
        out[:, 0] = cfg.cls_token
    return out


def dirichlet_partition(num_clients: int, num_classes: int, alpha: float,
                        seed: int = 0) -> np.ndarray:
    """Per-client class proportions ~ Dir(alpha): (N, C)."""
    rng = np.random.default_rng(seed)
    return rng.dirichlet([alpha] * num_classes, size=num_clients)


def quantity_skew(num_clients: int, total: int,
                  edge_of_client: Optional[List[int]] = None) -> np.ndarray:
    """|D_n| ∝ chi_n = (n+1)/Omega (§IV.A quantity skew)."""
    w = np.arange(1, num_clients + 1, dtype=np.float64)
    w = w / w.sum()
    sizes = np.maximum((w * total).astype(np.int64), 8)
    return sizes


def poison_labels(labels: np.ndarray, frac: float, num_classes: int,
                  rng) -> np.ndarray:
    """Randomly relabel a fraction of examples (unreliable clients)."""
    labels = labels.copy()
    n = len(labels)
    idx = rng.choice(n, size=int(frac * n), replace=False)
    labels[idx] = rng.integers(0, num_classes, size=len(idx))
    return labels


def poison_tokens(tokens: np.ndarray, frac: float, vocab_size: int,
                  rng) -> np.ndarray:
    """Scramble a fraction of sequences to uniform-random tokens — the
    causal-LM analogue of label poisoning (unreliable *text*, since
    labels never enter the next-token loss)."""
    tokens = tokens.copy()
    n = len(tokens)
    idx = rng.choice(n, size=int(frac * n), replace=False)
    tokens[idx] = rng.integers(0, vocab_size,
                               size=(len(idx), tokens.shape[1]))
    return tokens


def make_federation_data(cfg: SyntheticTaskConfig, num_clients: int,
                         total_examples: int, alpha: float,
                         poisoned_clients: Tuple[int, ...] = (),
                         poison_frac: float = 0.5,
                         seed: int = 0,
                         task_kind: str = "classification"
                         ) -> Dict[int, ClientData]:
    """Full §IV.A data generation: Dirichlet label skew + quantity skew +
    poisoning.  ``task_kind`` selects how unreliable clients corrupt
    their data: label flips ("classification") or sequence scrambles
    ("causal-lm"); the underlying corpora are identical."""
    rng = np.random.default_rng(seed)
    class_p = make_task(cfg)
    props = dirichlet_partition(num_clients, cfg.num_classes, alpha, seed + 1)
    sizes = quantity_skew(num_clients, total_examples)
    out = {}
    for n in range(num_clients):
        labels = rng.choice(cfg.num_classes, size=sizes[n], p=props[n])
        tokens = sample_examples(cfg, class_p, labels, rng)
        if n in poisoned_clients:
            if task_kind == "causal-lm":
                tokens = poison_tokens(tokens, poison_frac, cfg.vocab_size,
                                       rng)
            else:
                labels = poison_labels(labels, poison_frac,
                                       cfg.num_classes, rng)
        out[n] = ClientData(tokens=tokens, labels=labels.astype(np.int32),
                            poisoned=n in poisoned_clients)
    return out


def make_test_set(cfg: SyntheticTaskConfig, n: int, seed: int = 99):
    rng = np.random.default_rng(seed)
    class_p = make_task(cfg)
    labels = rng.integers(0, cfg.num_classes, size=n)
    tokens = sample_examples(cfg, class_p, labels, rng)
    return tokens, labels.astype(np.int32)
