from repro.data.synthetic import (SyntheticTaskConfig, make_task,
                                  dirichlet_partition, quantity_skew,
                                  poison_labels, ClientData)  # noqa: F401
from repro.data.probe import make_probe_set  # noqa: F401
from repro.data.pipeline import batch_iterator  # noqa: F401
