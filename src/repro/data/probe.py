"""Public probe set (ELSA §III.B.1 Step 1).

The cloud distributes Q *public* inputs to all clients as a common
behavioral reference.  Offline we sample label-free sequences from the
mixture of all class distributions (a stand-in for GLUE/TREC/SQuAD dev
samples); privacy is preserved since the probes carry no client data.
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import SyntheticTaskConfig, make_task


def make_probe_set(cfg: SyntheticTaskConfig, q: int, seed: int = 1234
                   ) -> np.ndarray:
    """(Q, S) int32 probe token sequences."""
    rng = np.random.default_rng(seed)
    class_p = make_task(cfg)
    mix = class_p.mean(0)
    out = np.empty((q, cfg.seq_len), np.int32)
    for i in range(q):
        out[i] = rng.choice(cfg.vocab_size, size=cfg.seq_len, p=mix)
    return out
