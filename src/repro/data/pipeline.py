"""Batching / shuffling pipeline over client datasets.

Besides the per-client epoch iterators, this module builds the
pre-gathered batch *stacks* the batched federation engine scans over:
``stack_padded_batches`` pulls ``steps`` batches per client, pads ragged
epoch-tail batches to a fixed batch size with zero-weight rows, and
stacks them to ``(steps, clients, batch, ...)`` device arrays so a whole
local round is a single ``lax.scan`` over one compiled shape.
"""
from __future__ import annotations

from typing import Iterator, List, Sequence, Tuple

import numpy as np


def batch_iterator(tokens: np.ndarray, labels: np.ndarray, batch_size: int,
                   *, shuffle: bool = True, seed: int = 0, drop_last: bool = False
                   ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Epoch iterator yielding (tokens, labels) batches."""
    n = len(tokens)
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n) if shuffle else np.arange(n)
    stop = (n // batch_size) * batch_size if drop_last else n
    for i in range(0, stop, batch_size):
        sel = idx[i:i + batch_size]
        if len(sel) == 0:
            continue
        yield tokens[sel], labels[sel]


def infinite_batches(tokens: np.ndarray, labels: np.ndarray,
                     batch_size: int, seed: int = 0):
    epoch = 0
    while True:
        for b in batch_iterator(tokens, labels, batch_size,
                                seed=seed + epoch):
            yield b
        epoch += 1


class CountingIterator:
    """Iterator wrapper that counts draws, so a seeded stream can be
    reproduced exactly after a restart: checkpoint the count, rebuild
    the same seeded iterator in the new process, and
    :meth:`fast_forward` to it.  Federation checkpointing
    (:mod:`repro.checkpoint.federation`) relies on this for the
    per-client batch streams."""

    def __init__(self, it):
        self._it = it
        self.count = 0

    def __iter__(self):
        return self

    def __next__(self):
        out = next(self._it)
        self.count += 1
        return out

    def fast_forward(self, count: int) -> None:
        """Discard draws until ``self.count == count``."""
        if count < self.count:
            raise ValueError(
                f"cannot rewind an iterator (at {self.count}, "
                f"asked for {count})")
        while self.count < count:
            next(self)


def pad_batch(tokens: np.ndarray, labels: np.ndarray, batch_size: int
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad a ragged (b, S) batch to ``batch_size`` rows.

    Returns (tokens, labels, weights) with weights 1.0 on real rows and
    0.0 on padding; the weighted loss then matches the unpadded mean
    exactly (padding contributes exact zeros).
    """
    b = len(tokens)
    w = np.zeros(batch_size, np.float32)
    w[:b] = 1.0
    if b == batch_size:
        return tokens, labels, w
    pt = np.zeros((batch_size,) + tokens.shape[1:], tokens.dtype)
    pl = np.zeros((batch_size,) + labels.shape[1:], labels.dtype)
    pt[:b], pl[:b] = tokens, labels
    return pt, pl, w


def stack_padded_batches(per_client: Sequence[List[Tuple[np.ndarray,
                                                         np.ndarray]]],
                         batch_size: int
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack per-client batch sequences into scan-ready arrays.

    ``per_client``: one list of ``steps`` (tokens, labels) batches per
    client (already drawn from that client's iterator, preserving its
    shuffle order).  Returns host arrays
    ``tokens (steps, N, B, S) int32``, ``labels (steps, N, B) int32``,
    ``weights (steps, N, B) float32`` — step axis leading so a
    ``lax.scan`` over local steps consumes one (N, B, ...) slice per
    iteration.
    """
    steps = len(per_client[0])
    assert all(len(c) == steps for c in per_client), \
        "all clients must contribute the same number of local steps"
    toks, labs, wts = [], [], []
    for s in range(steps):
        trow, lrow, wrow = [], [], []
        for client in per_client:
            t, l, w = pad_batch(client[s][0], client[s][1], batch_size)
            trow.append(t)
            lrow.append(l)
            wrow.append(w)
        toks.append(np.stack(trow))
        labs.append(np.stack(lrow))
        wts.append(np.stack(wrow))
    return (np.stack(toks).astype(np.int32), np.stack(labs).astype(np.int32),
            np.stack(wts))
