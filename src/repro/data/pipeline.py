"""Batching / shuffling pipeline over client datasets."""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def batch_iterator(tokens: np.ndarray, labels: np.ndarray, batch_size: int,
                   *, shuffle: bool = True, seed: int = 0, drop_last: bool = False
                   ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Epoch iterator yielding (tokens, labels) batches."""
    n = len(tokens)
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n) if shuffle else np.arange(n)
    stop = (n // batch_size) * batch_size if drop_last else n
    for i in range(0, stop, batch_size):
        sel = idx[i:i + batch_size]
        if len(sel) == 0:
            continue
        yield tokens[sel], labels[sel]


def infinite_batches(tokens: np.ndarray, labels: np.ndarray,
                     batch_size: int, seed: int = 0):
    epoch = 0
    while True:
        for b in batch_iterator(tokens, labels, batch_size,
                                seed=seed + epoch):
            yield b
        epoch += 1
