"""Scheduler policies for the event-driven edge runtime.

All three schedulers drive the same training machinery — the
federation's compiled :class:`~repro.federation.engine.BatchedEngine`
via ``Federation._edge_round`` (which buckets whatever ready-set it is
handed by split configuration) — and differ only in *when* edge and
cloud aggregations happen on the simulated clock:

- :class:`SyncScheduler`: barrier per edge round.  With no churn this
  issues the exact same sequence of training/aggregation calls as the
  historical ``Federation.run`` loop, so histories are bit-identical on
  the batched backend; it additionally prices every round in simulated
  seconds (the barrier waits for the slowest straggler, churn pauses
  included).
- :class:`DeadlineScheduler`: the edge aggregates whoever reported
  within a per-round deadline; stragglers keep training and their
  updates carry over into a later aggregation with a per-round-late
  weight discount.
- :class:`AsyncScheduler`: the edge folds each arrival into its model
  continuously with staleness-discounted mixing weights (FedAsync-style)
  and the cloud fuses edge models on a fixed period.

All three inject faults from ``RuntimeConfig.faults`` (a seeded
:class:`~repro.federation.topology.FaultTrace`): crashes lose in-flight
work, drops lose the uplink after training, dups deliver it twice, and
corruptions mangle the arriving adapter update — each sampled per
dispatch, so the schedule is identical whether screening is on or off.
The sync policy additionally supports full-state checkpoint/resume
(:mod:`repro.checkpoint.federation`): resuming a killed run reproduces
the uninterrupted history bit-identically (docs/robustness.md).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import numpy as np

from repro import telemetry as tm
from repro.core import aggregation as agg
from repro.data.pipeline import CountingIterator, infinite_batches
from repro.federation.topology import corrupt_update
from repro.runtime.client import ClientRuntimeState
from repro.runtime.events import (ARRIVAL, CLOUD_AGG, CORRUPT, CRASH,
                                  DISPATCH, DROP, DUP, EDGE_AGG, EVAL,
                                  OFFLINE, REJOIN, Event, EventQueue)

ELSA_METHODS = ("elsa", "elsa-fixed", "elsa-nocluster")


def _mix(theta, update, w: float, mode: str = "factor"):
    """theta <- (1-w) theta + w update (async edge fold); in product
    mode the mix happens in weight-delta space (factor-space mixing has
    the same cross-term cancellation as factor averaging)."""
    return agg.mix_adapters(theta, update, w, mode=mode)


class _SchedulerBase:
    def __init__(self, rt):
        self.rt = rt
        self.fed = rt.federation
        self.fc = rt.federation.fed
        self.cost = rt.cost
        self.churn = rt.churn
        self.trace = rt.trace
        self.rcfg = rt.config
        # registry-backed population binding, if Federation.run /
        # EdgeRuntime.run installed one (None -> legacy dict path)
        self.pop = rt.federation._population

    # -- shared setup ------------------------------------------------------
    def _setup(self, method: str, assign: bool = True):
        """Shared run preamble.  ``assign=False`` skips the expensive
        clustering phase — a resumed run restores groups/div/trust (and
        the channels the clustering built) from its checkpoint instead
        of recomputing them."""
        fc = self.fc
        rng = np.random.default_rng(fc.seed + 5)
        groups = div = trust = None
        if assign:
            with tm.span("profile", method=method):
                groups, div, trust = self.fed._assign_groups(method, rng)
            if self.pop is not None:
                self.pop.after_assign(groups)
        iters = self.pop.iters if self.pop is not None else \
            {n: CountingIterator(
                 infinite_batches(self.fed.data[n].tokens,
                                  self.fed.data[n].labels,
                                  fc.batch_size,
                                  seed=fc.seed + 100 + n))
             for n in range(fc.n_clients)}
        server_opt = self.fed.server_optimizer(method)
        server_state = server_opt.init(self.fed.lora0) if server_opt \
            else None
        return rng, groups, div, trust, iters, server_opt, server_state

    def _sample_fault(self, n: int, dispatch_idx: int):
        faults = self.rcfg.faults
        return faults.sample(n, dispatch_idx) if faults is not None \
            else None

    def _round_seconds(self, n: int, use_split: bool, steps: int,
                       edge: int, round_idx: int) -> float:
        rc = self.cost.round_cost(
            n, self.fed.split_for(n, use_split), steps,
            edge, round_idx)
        if tm.enabled():
            # per-phase simulated seconds + wire bytes, one bill per
            # dispatch (docs/observability.md: the sim-time breakdown
            # lives in counters, wall time in spans)
            tm.inc("runtime.sim.compute_s", rc.compute_s)
            tm.inc("runtime.sim.uplink_s", rc.comm_s)
            tm.inc("runtime.sim.downlink_s", rc.downlink_s)
            tm.inc("runtime.sim.latency_s", rc.latency_s)
            tm.inc("runtime.uplink_bytes", rc.uplink_bytes)
            tm.inc("runtime.downlink_bytes", rc.downlink_bytes)
        return rc.total_s

    # -- cloud fusion (identical math to Federation.run) -------------------
    def _cloud_fuse(self, method: str, edge_thetas, edge_alphas, theta,
                    server_opt, server_state):
        mode = self.fc.aggregate
        if method in ELSA_METHODS:
            theta_new = agg.cloud_aggregate(edge_thetas, edge_alphas,
                                            mode=mode)
        else:
            ws = {k: 1.0 for k in edge_thetas}
            theta_new = agg.cloud_aggregate(edge_thetas, ws, mode=mode)
        if server_opt is not None:
            pseudo = jax.tree_util.tree_map(lambda a, b: a - b, theta,
                                            theta_new)
            theta_new, server_state = server_opt.update(theta, pseudo,
                                                        server_state)
        delta = agg.global_delta(theta_new, theta)
        return theta_new, server_state, delta

    def _edge_alpha(self, div, trust, members) -> float:
        return agg.edge_weight(agg.mean_pairwise_kld(div, members),
                               self.fed.fusion_trust(trust, members))

    def _record_eval(self, history, round_idx: int, t: float, theta,
                     losses, delta: float, log: bool, label: str) -> None:
        """Evaluate + append one history/trace point (all policies)."""
        with tm.span("eval", round=round_idx):
            acc = self.fed.evaluate(theta)
        self.trace.log(t, EVAL, round=round_idx, accuracy=acc)
        history["round"].append(round_idx)
        history["time"].append(t)
        history["accuracy"].append(acc)
        history["loss"].append(
            float(np.mean(losses)) if losses else float("nan"))
        history["delta"].append(delta)
        if log:
            print(f"[{label}] round {round_idx}: t={t:.1f}s "
                  f"acc={acc:.4f} loss={history['loss'][-1]:.4f}")

    def _finish_history(self, history, theta, client_losses):
        if not history["accuracy"]:
            # simulation hit max_sim_s before the first eval point
            history["round"].append(0)
            history["time"].append(0.0)
            history["accuracy"].append(self.fed.evaluate(theta))
            history["loss"].append(float("nan"))
            history["delta"].append(float("nan"))
        history["final_accuracy"] = history["accuracy"][-1]
        history["client_losses"] = client_losses
        self.fed.last_theta = theta
        return history


# ---------------------------------------------------------------------------
# sync: barrier semantics, priced in wall-clock
# ---------------------------------------------------------------------------

class SyncScheduler(_SchedulerBase):
    """Reproduces ``Federation.run`` exactly (same dispatch sequence,
    same aggregation order) while assigning every round a simulated
    duration: each edge round ends when its slowest participant finishes
    (churn pauses included); the cloud waits for the slowest edge.

    Crash faults lose the client's round entirely — it contributes no
    update, no loss, and the barrier does not wait for it (the edge
    times it out); drops train and count toward the barrier but the
    uplink is lost; dups fold the update twice; corruptions mangle it
    in flight.  This is the only policy supporting checkpoint/resume:
    at a global-round boundary the whole scheduler state is in
    (theta, server_state, rng, iterator cursors, dispatch counters,
    clock), which :mod:`repro.checkpoint.federation` serializes.
    """

    def run(self, method: str, global_rounds: int, steps_per_round: int,
            eval_every: int, log: bool, checkpoint=None,
            resume_from: Optional[str] = None) -> Dict:
        from repro.checkpoint import federation as fedckpt
        fed, fc = self.fed, self.fc
        use_split_dyn = method not in ("elsa-fixed",)
        rng, groups, div, trust, iters, server_opt, server_state = \
            self._setup(method, assign=resume_from is None)
        history = {"round": [], "time": [], "accuracy": [], "loss": [],
                   "delta": []}
        client_losses: Dict[int, List[float]] = {
            n: [] for n in range(fc.n_clients)}
        theta = fed.lora0
        t_global = 0.0
        disp = {n: 0 for n in range(fc.n_clients)}  # fault cursors
        start_round, last_delta = 0, float("inf")

        if resume_from is not None:
            state = fedckpt.load_state(fedckpt.resolve(resume_from))
            res = fedckpt.restore_run(fed, state, method=method,
                                      steps_per_round=steps_per_round,
                                      iters=iters, rng=rng,
                                      population=self.pop)
            groups, div, trust = res.groups, res.div, res.trust
            theta, server_state = res.theta, res.server_state
            history, client_losses = res.history, res.client_losses
            start_round, last_delta = res.round_idx + 1, res.delta
            t_global = res.t_global
            disp.update(res.dispatches)
            if res.trace_records is not None:
                self.trace.records = list(res.trace_records)
            if last_delta <= fc.xi or t_global >= self.rcfg.max_sim_s:
                return self._finish_history(history, theta, client_losses)
        ckpt = fedckpt.Checkpointer(checkpoint) if checkpoint else None

        for g in range(start_round, global_rounds):
            if self.pop is not None:
                self.pop.begin_round(g, t=t_global)
            edge_thetas, edge_alphas, losses = {}, {}, []
            edge_done = {}
            for k, members in groups.items():
                if not members:
                    continue
                active = members
                if method == "fedavg-random":
                    m = max(1, len(members) // 2)
                    active = list(rng.choice(members, m, replace=False))
                theta_k = theta
                t_k = t_global
                for r in range(fc.t_rounds):
                    with tm.span("dispatch", round=g, edge=k) as sp_d:
                        avail = [n for n in active
                                 if self.churn.is_online(n, t_k)]
                        while not avail:
                            # whole cohort offline: the barrier waits for
                            # the first rejoin (finite churn traces
                            # guarantee one)
                            t_k = min(self.churn.next_online(n, t_k)
                                      for n in active
                                      if not self.churn.is_online(n, t_k))
                            avail = [n for n in active
                                     if self.churn.is_online(n, t_k)]
                        for n in avail:
                            self.trace.log(t_k, DISPATCH, n, k, round=g,
                                           edge_round=r)
                        for n in active:
                            if n not in avail:
                                self.trace.log(t_k, OFFLINE, n, k,
                                               round=g, edge_round=r)
                        sp_d.set(n_clients=len(avail))
                    with tm.span("local_steps", round=g, edge=k,
                                 n_clients=len(avail)):
                        locals_, weights, loss_map = fed._edge_round(
                            avail, theta_k, steps_per_round, iters,
                            use_split=use_split_dyn,
                            prox_anchor=(theta if method == "fedprox"
                                         else None))
                    barrier = t_k
                    upds, wts, senders = [], [], []
                    with tm.span("uplink", round=g, edge=k) as sp_u:
                        for lora_n, w_n, n in zip(locals_, weights, avail):
                            fault = self._sample_fault(n, disp[n])
                            disp[n] += 1
                            dur = self._round_seconds(n, use_split_dyn,
                                                      steps_per_round, k, g)
                            f_n = self.churn.finish_time(n, t_k, dur)
                            if fault is not None and fault.kind == "crash":
                                # work lost, not paused: no update, no
                                # loss, and the barrier does not wait
                                t_c = t_k + fault.at_frac \
                                    * max(f_n - t_k, 0.0)
                                self.trace.log(t_c, CRASH, n, k, round=g,
                                               edge_round=r)
                                continue
                            self.trace.log(f_n, ARRIVAL, n, k, round=g)
                            barrier = max(barrier, f_n)
                            losses.append(loss_map[n])
                            client_losses[n].append(loss_map[n])
                            if fault is not None and fault.kind == "drop":
                                self.trace.log(f_n, DROP, n, k, round=g)
                                continue
                            if fault is not None and fault.kind == "corrupt":
                                lora_n = corrupt_update(theta_k, lora_n,
                                                        fault)
                                self.trace.log(f_n, CORRUPT, n, k, round=g,
                                               mode=fault.mode)
                            upds.append(lora_n)
                            wts.append(w_n)
                            senders.append(n)
                            if fault is not None and fault.kind == "dup":
                                upds.append(lora_n)
                                wts.append(w_n)
                                senders.append(n)
                                self.trace.log(f_n, DUP, n, k, round=g)
                        sp_u.set(sim_s=barrier - t_k, n_updates=len(upds))
                    if upds:
                        if self.pop is not None:
                            self.pop.note_updates(senders, upds, theta_k)
                        with tm.span("edge_agg", round=g, edge=k,
                                     n_updates=len(upds)):
                            theta_k = fed.screened_aggregate(
                                senders, upds, wts, theta_k)
                    # else: every uplink was lost; the edge keeps its model
                    t_k = barrier
                    self.trace.log(t_k, EDGE_AGG, -1, k, round=g,
                                   n_updates=len(upds))
                edge_thetas[k] = theta_k
                edge_alphas[k] = self._edge_alpha(div, trust, active)
                edge_done[k] = t_k

            t_global = max(edge_done.values()) + self.rt.backhaul_s
            with tm.span("cloud_agg", round=g, n_edges=len(edge_thetas)):
                theta, server_state, delta = self._cloud_fuse(
                    method, edge_thetas, edge_alphas, theta, server_opt,
                    server_state)
            self.trace.log(t_global, CLOUD_AGG, round=g,
                           n_edges=len(edge_thetas))
            if g % eval_every == 0 or g == global_rounds - 1:
                self._record_eval(history, g, t_global, theta, losses,
                                  delta, log, f"sync/{method}")
            if self.pop is not None:
                self.pop.end_round(g)
            if ckpt is not None and ckpt.due(g, global_rounds - 1, delta,
                                             fc.xi):
                ckpt.save(g, fedckpt.build_state(
                    fed, method=method, steps_per_round=steps_per_round,
                    round_idx=g, theta=theta, server_state=server_state,
                    rng=rng, iters=iters, history=history,
                    client_losses=client_losses, groups=groups, div=div,
                    trust=trust, delta=delta, t_global=t_global,
                    dispatches=disp, trace_records=self.trace.records,
                    population=self.pop))
            tm.end_round(g, sim_time_s=t_global)
            if delta <= fc.xi or t_global >= self.rcfg.max_sim_s:
                break
        return self._finish_history(history, theta, client_losses)


# ---------------------------------------------------------------------------
# deadline: bounded edge rounds, straggler carry-over
# ---------------------------------------------------------------------------

class DeadlineScheduler(_SchedulerBase):
    """Edge rounds end at ``start + deadline_s``; whoever reported in the
    window is folded into the edge model by partial-participation
    averaging — the current ``theta_k`` is weighted by the cohort mass
    that did *not* report, so late windows perturb rather than replace
    it — with stragglers from earlier rounds discounted by
    ``straggler_discount**rounds_late``.  Clients still training at the
    deadline are simply not re-dispatched until they finish — their work
    is never thrown away, it just arrives late (unless a fault crashes
    it mid-flight or drops the uplink)."""

    def run(self, method: str, global_rounds: int, steps_per_round: int,
            eval_every: int, log: bool, checkpoint=None,
            resume_from: Optional[str] = None) -> Dict:
        # checkpoint/resume kwargs are rejected upstream by EdgeRuntime
        # for non-sync policies; they reach here only as None
        fed, fc = self.fed, self.fc
        use_split_dyn = method not in ("elsa-fixed",)
        rng, groups, div, trust, iters, server_opt, server_state = \
            self._setup(method)
        history = {"round": [], "time": [], "accuracy": [], "loss": [],
                   "delta": []}
        client_losses: Dict[int, List[float]] = {
            n: [] for n in range(fc.n_clients)}
        theta = fed.lora0
        t_global = 0.0

        placed = [n for ms in groups.values() for n in ms]
        deadline_s = self.rcfg.deadline_s
        if deadline_s is None:
            est = self.cost.estimate_population(
                {n: fed.split_for(n, use_split_dyn) for n in placed},
                steps_per_round)
            deadline_s = float(np.quantile(list(est.values()),
                                           self.rcfg.deadline_quantile))
        states = {n: ClientRuntimeState(n) for n in placed}
        queues = {k: EventQueue() for k, ms in groups.items() if ms}
        edge_round_idx = {k: 0 for k in queues}

        for g in range(global_rounds):
            if self.pop is not None:
                self.pop.begin_round(g, t=t_global)
            edge_thetas, edge_alphas, losses = {}, {}, []
            edge_done = {}
            for k, members in groups.items():
                if not members:
                    continue
                active = members
                if method == "fedavg-random":
                    m = max(1, len(members) // 2)
                    active = list(rng.choice(members, m, replace=False))
                theta_k = theta
                t_k = t_global
                for _ in range(fc.t_rounds):
                    t_k, theta_k = self._edge_deadline_round(
                        k, active, theta_k, t_k, deadline_s,
                        steps_per_round, iters, method, theta,
                        use_split_dyn, states, queues[k], edge_round_idx,
                        losses, client_losses, g)
                edge_thetas[k] = theta_k
                edge_alphas[k] = self._edge_alpha(div, trust, active)
                edge_done[k] = t_k

            t_global = max(edge_done.values()) + self.rt.backhaul_s
            with tm.span("cloud_agg", round=g, n_edges=len(edge_thetas)):
                theta, server_state, delta = self._cloud_fuse(
                    method, edge_thetas, edge_alphas, theta, server_opt,
                    server_state)
            self.trace.log(t_global, CLOUD_AGG, round=g,
                           n_edges=len(edge_thetas))
            if g % eval_every == 0 or g == global_rounds - 1:
                self._record_eval(history, g, t_global, theta, losses,
                                  delta, log, f"deadline/{method}")
            if self.pop is not None:
                self.pop.end_round(g)
            tm.end_round(g, sim_time_s=t_global)
            if delta <= fc.xi or t_global >= self.rcfg.max_sim_s:
                break
        return self._finish_history(history, theta, client_losses)

    # ------------------------------------------------------------------
    def _edge_deadline_round(self, k, active, theta_k, t_k, deadline_s,
                             steps, iters, method, theta_anchor,
                             use_split_dyn, states, queue, edge_round_idx,
                             losses, client_losses, g):
        """One deadline-bounded edge round; returns (t_end, theta_k)."""
        fed = self.fed
        r_idx = edge_round_idx[k]
        while True:
            ready = [n for n in active if states[n].idle
                     and self.churn.is_online(n, t_k)]
            if ready:
                with tm.span("local_steps", round=g, edge=k,
                             n_clients=len(ready)):
                    locals_, _, loss_map = fed._edge_round(
                        ready, theta_k, steps, iters,
                        use_split=use_split_dyn,
                        prox_anchor=(theta_anchor if method == "fedprox"
                                     else None))
                for lora_n, n in zip(locals_, ready):
                    fault = self._sample_fault(n, states[n].dispatches)
                    dur = self._round_seconds(n, use_split_dyn, steps, k,
                                              states[n].rounds_run)
                    f_n = self.churn.finish_time(n, t_k, dur)
                    if self.pop is not None:
                        # a straggler may arrive after a cohort swap:
                        # remember who actually trained in this slot
                        self.pop.pin(n)
                    states[n].dispatch(t_k, f_n, 0, r_idx)
                    if fault is not None and fault.kind == "crash":
                        t_c = t_k + fault.at_frac * max(f_n - t_k, 0.0)
                        queue.push(Event(t_c, CRASH, n, k))
                    else:
                        if fault is not None and fault.kind == "corrupt":
                            lora_n = corrupt_update(theta_k, lora_n,
                                                    fault)
                        queue.push(Event(f_n, ARRIVAL, n, k,
                                         payload=(lora_n, loss_map[n],
                                                  fault)))
                    self.trace.log(t_k, DISPATCH, n, k, round=g,
                                   edge_round=r_idx)
            if queue:
                break
            # nothing in flight and nobody dispatchable: jump to the
            # first rejoin among idle members and retry
            t_k = min(self.churn.next_online(n, t_k) for n in active
                      if states[n].idle
                      and not self.churn.is_online(n, t_k))

        deadline = t_k + deadline_s
        nxt = queue.peek()
        if nxt.time > deadline:
            # nobody would report in the window — stretch it to the first
            # arrival so an edge round never aggregates nothing
            deadline = nxt.time
        upds, wts, senders, n_late, rep_w = [], [], [], 0, 0.0
        note_ids = []
        with tm.span("uplink", round=g, edge=k) as sp_u:
            for ev in queue.drain_until(deadline):
                n = ev.client
                if ev.kind == CRASH:
                    # in-flight work lost; the client idles and is
                    # eligible for re-dispatch from the next window
                    states[n].crash()
                    self.trace.log(ev.time, CRASH, n, k, round=g)
                    continue
                states[n].complete(ev.payload)
                lora_n, loss_n, fault = states[n].collect()
                late = r_idx - states[n].base_round
                losses.append(loss_n)
                client_losses[n].append(loss_n)
                self.trace.log(ev.time, ARRIVAL, n, k, round=g, late=late)
                if fault is not None and fault.kind == "drop":
                    # trained (loss counted) but the uplink was lost: not
                    # folded, and its mass stays with the absent cohort
                    self.trace.log(ev.time, DROP, n, k, round=g)
                    continue
                if fault is not None and fault.kind == "corrupt":
                    self.trace.log(ev.time, CORRUPT, n, k, round=g,
                                   mode=fault.mode)
                w = fed.client_weight(n) \
                    * (self.rcfg.straggler_discount ** late)
                upds.append(lora_n)
                wts.append(w)
                senders.append(n)
                if self.pop is not None:
                    note_ids.append(self.pop.pinned(n))
                rep_w += fed.client_weight(n)
                n_late += int(late > 0)
                if fault is not None and fault.kind == "dup":
                    upds.append(lora_n)
                    wts.append(w)
                    senders.append(n)
                    if self.pop is not None:
                        note_ids.append(self.pop.pinned(n))
                    self.trace.log(ev.time, DUP, n, k, round=g)
            sp_u.set(sim_s=deadline - t_k, n_updates=len(upds),
                     n_stragglers=n_late)
        if tm.enabled() and n_late:
            # straggler carry-overs folded this window (late > 0 rounds)
            tm.inc("runtime.stragglers", n_late)
        if self.pop is not None and upds:
            # stragglers write back under their pinned dispatch-time
            # identity; the delta base is the window's edge model (a
            # straggler's true dispatch model is gone — documented
            # approximation, the registry column is off the math path)
            self.pop.note_updates(senders, upds, theta_k, ids=note_ids)
        with tm.span("edge_agg", round=g, edge=k, n_updates=len(upds)):
            if self.fc.screen and upds:
                upds, wts = fed.screen_cohort(senders, upds, wts, theta_k)
            # partial participation: the current edge model stands in for
            # the cohort mass that did NOT report this window, so a lone
            # (possibly stale, discounted) arrival perturbs theta_k
            # proportionally instead of replacing it — fedavg's weight
            # normalization would otherwise cancel the straggler discount
            # whenever a window's arrivals are uniformly late
            absent_w = max(float(sum(fed.client_weight(n)
                                     for n in active)) - rep_w, 0.0)
            if upds and absent_w > 0:
                theta_k = agg.aggregate_adapters([theta_k] + upds,
                                                 [absent_w] + wts,
                                                 mode=self.fc.aggregate)
            elif upds:
                theta_k = agg.aggregate_adapters(upds, wts,
                                                 mode=self.fc.aggregate)
            # else: every uplink this window was lost or screened out;
            # the edge keeps its model
        self.trace.log(deadline, EDGE_AGG, -1, k, round=g,
                       n_updates=len(upds), n_stragglers=n_late)
        edge_round_idx[k] = r_idx + 1
        return deadline, theta_k


# ---------------------------------------------------------------------------
# async: continuous staleness-weighted folding, periodic cloud fusion
# ---------------------------------------------------------------------------

class AsyncScheduler(_SchedulerBase):
    """FedAsync-style hierarchical execution: every arrival is folded
    into its edge model immediately with weight
    ``alpha / (1 + staleness)^decay`` (staleness = edge-model versions
    since dispatch) and the client is re-dispatched from the fresh edge
    model; the cloud fuses all edge models every ``cloud_period_s``
    simulated seconds and broadcasts the result back to the edges.
    ``global_rounds`` counts cloud fusions.

    ``fedavg-random`` keeps its partial-participation semantics here
    too: each cloud-fusion window samples half of every edge's members
    as the active cohort — only cohort members are (re-)dispatched, and
    the fusion's edge weights are computed over the *actually-sampled*
    cohort, not the full membership (which would silently degrade the
    baseline to full participation)."""

    def run(self, method: str, global_rounds: int, steps_per_round: int,
            eval_every: int, log: bool, checkpoint=None,
            resume_from: Optional[str] = None) -> Dict:
        # checkpoint/resume kwargs are rejected upstream by EdgeRuntime
        # for non-sync policies; they reach here only as None
        fed, fc = self.fed, self.fc
        use_split_dyn = method not in ("elsa-fixed",)
        rng, groups, div, trust, iters, server_opt, server_state = \
            self._setup(method)
        history = {"round": [], "time": [], "accuracy": [], "loss": [],
                   "delta": []}
        client_losses: Dict[int, List[float]] = {
            n: [] for n in range(fc.n_clients)}

        groups = {k: ms for k, ms in groups.items() if ms}
        theta = fed.lora0
        edge_theta = {k: theta for k in groups}
        version = {k: 0 for k in groups}
        states = {n: ClientRuntimeState(n)
                  for ms in groups.values() for n in ms}
        queue = EventQueue()
        self._steps = steps_per_round
        self._use_split_dyn = use_split_dyn
        self._method = method
        self._iters = iters
        self._anchor = theta

        def sample_cohort():
            """Per-fusion-window active set per edge (fedavg-random
            subsamples half the members, like the sync/deadline loops
            do per global round; other methods run everyone)."""
            if method != "fedavg-random":
                return {k: list(ms) for k, ms in groups.items()}
            return {k: sorted(int(x) for x in
                              rng.choice(ms, max(1, len(ms) // 2),
                                         replace=False))
                    for k, ms in groups.items()}

        cohort = sample_cohort()

        period = self.rcfg.cloud_period_s
        if period is None:
            est = self.cost.estimate_population(
                {n: fed.split_for(n, use_split_dyn) for n in states},
                steps_per_round)
            period = fc.t_rounds * float(np.median(list(est.values()))) \
                + self.rt.backhaul_s

        if self.pop is not None:
            # the async cohort swaps per fusion window, not per round
            self.pop.begin_round(0, t=0.0)
        # initial dispatch: every online cohort member, batched per edge
        for k in groups:
            ready = [n for n in cohort[k] if self.churn.is_online(n, 0.0)]
            if ready:
                self._dispatch(ready, k, 0.0, edge_theta[k], version[k],
                               states, queue)
            for n in cohort[k]:
                if n not in ready:
                    queue.push(Event(self.churn.next_online(n, 0.0),
                                     REJOIN, n, k))
        queue.push(Event(period, CLOUD_AGG))

        fusions = 0
        window_losses: List[float] = []
        while queue and fusions < global_rounds:
            ev = queue.pop()
            t = ev.time
            if t > self.rcfg.max_sim_s:
                break
            if ev.kind == ARRIVAL:
                n, k = ev.client, ev.edge
                states[n].complete(ev.payload)
                lora_n, loss_n, fault = states[n].collect()
                s = states[n].staleness(version[k])
                w = min(1.0, self.rcfg.async_alpha
                        / (1.0 + s) ** self.rcfg.staleness_decay)
                folds = 1
                if fault is not None and fault.kind == "drop":
                    folds = 0   # trained, but the uplink was lost
                elif fault is not None and fault.kind == "dup":
                    folds = 2   # delivered (and folded) twice
                if fc.screen and folds:
                    # no cohort to median against here — each arrival is
                    # screened alone (finite check) and trust-discounted;
                    # norm/direction screens need the batched cohorts of
                    # the sync/deadline paths (docs/robustness.md)
                    from repro.core.screening import (LOW_TRUST,
                                                      NONFINITE, OK)
                    from repro.federation.engine import screen_stats
                    fin, _, _ = screen_stats(edge_theta[k], [lora_n],
                                             [1.0])
                    ok = bool(fin[0])
                    if self.pop is not None:
                        # the verdict belongs to whoever trained the
                        # update: the pinned dispatch-time identity,
                        # not slot n's current occupant
                        cid = self.pop.pinned(n)
                        self.pop.record_trust(cid, ok)
                        score = self.pop.trust_weight(cid)
                    else:
                        fed.trust_ledger.record(n, ok)
                        score = float(fed.trust_ledger.scores[n])
                    if not ok or score < fed.screening.trust_floor:
                        folds = 0
                    if tm.enabled():
                        v = NONFINITE if not ok else \
                            (OK if folds else LOW_TRUST)
                        tm.inc("screening.verdicts", 1, verdict=v)
                    if folds:
                        w = min(1.0, w * score)
                if folds and self.pop is not None:
                    # write back under the dispatch-time identity (the
                    # cohort may have swapped since); delta base is the
                    # current pre-fold edge model
                    self.pop.note_updates([n], [lora_n], edge_theta[k],
                                          ids=[self.pop.pinned(n)])
                for _ in range(folds):
                    edge_theta[k] = _mix(edge_theta[k], lora_n, w,
                                         mode=fc.aggregate)
                    version[k] += 1
                window_losses.append(loss_n)
                client_losses[n].append(loss_n)
                self.trace.log(t, ARRIVAL, n, k, staleness=s,
                               weight=round(w, 6))
                if fault is not None and fault.kind == "drop":
                    self.trace.log(t, DROP, n, k)
                elif fault is not None and fault.kind == "dup":
                    self.trace.log(t, DUP, n, k)
                elif fault is not None and fault.kind == "corrupt":
                    self.trace.log(t, CORRUPT, n, k, mode=fault.mode)
                if n not in cohort[k]:
                    pass   # dropped from the current cohort: stay idle
                elif self.churn.is_online(n, t):
                    self._dispatch([n], k, t, edge_theta[k], version[k],
                                   states, queue)
                else:
                    queue.push(Event(self.churn.next_online(n, t),
                                     REJOIN, n, k))
            elif ev.kind == CRASH:
                n, k = ev.client, ev.edge
                states[n].crash()
                self.trace.log(t, CRASH, n, k)
                if n not in cohort[k]:
                    pass   # crashed out of a stale cohort: stay idle
                elif self.churn.is_online(n, t):
                    self._dispatch([n], k, t, edge_theta[k], version[k],
                                   states, queue)
                else:
                    queue.push(Event(self.churn.next_online(n, t),
                                     REJOIN, n, k))
            elif ev.kind == REJOIN:
                n, k = ev.client, ev.edge
                if not (states[n].idle and n in cohort[k]):
                    pass   # mid-flight, or no longer sampled this window
                elif self.churn.is_online(n, t):
                    self._dispatch([n], k, t, edge_theta[k], version[k],
                                   states, queue)
                else:
                    queue.push(Event(self.churn.next_online(n, t),
                                     REJOIN, n, k))
            elif ev.kind == CLOUD_AGG:
                fusions += 1
                # weight every edge by the cohort that actually trained
                # this window (== full membership except fedavg-random)
                alphas = {k: self._edge_alpha(div, trust, cohort[k])
                          for k in groups}
                with tm.span("cloud_agg", round=fusions - 1,
                             n_edges=len(groups)):
                    theta, server_state, delta = self._cloud_fuse(
                        method, edge_theta, alphas, theta, server_opt,
                        server_state)
                self._anchor = theta
                for k in groups:       # broadcast fused model to edges
                    edge_theta[k] = theta
                    version[k] += 1
                self.trace.log(t, CLOUD_AGG, round=fusions - 1,
                               n_edges=len(groups))
                if (fusions - 1) % eval_every == 0 \
                        or fusions == global_rounds:
                    self._record_eval(history, fusions - 1, t, theta,
                                      window_losses, delta, log,
                                      f"async/{method}")
                    # reset only once recorded, so with eval_every > 1
                    # the loss covers every window since the last eval
                    window_losses = []
                if self.pop is not None:
                    self.pop.end_round(fusions - 1)
                tm.end_round(fusions - 1, sim_time_s=t)
                if delta <= fc.xi:
                    break
                if fusions < global_rounds:
                    if self.pop is not None:
                        self.pop.begin_round(fusions, t=t)
                    cohort = sample_cohort()   # next window's active set
                    for k in groups:           # wake newly-sampled idlers
                        ready = [n for n in cohort[k] if states[n].idle
                                 and self.churn.is_online(n, t)]
                        if ready:
                            self._dispatch(ready, k, t, edge_theta[k],
                                           version[k], states, queue)
                        for n in cohort[k]:
                            if states[n].idle and n not in ready:
                                queue.push(Event(
                                    self.churn.next_online(n, t),
                                    REJOIN, n, k))
                    queue.push(Event(t + period, CLOUD_AGG))
        return self._finish_history(history, theta, client_losses)

    # ------------------------------------------------------------------
    def _dispatch(self, ready: List[int], k: int, t: float, theta_k,
                  version_k: int, states, queue) -> None:
        fed = self.fed
        with tm.span("local_steps", edge=k, n_clients=len(ready)):
            locals_, _, loss_map = fed._edge_round(
                ready, theta_k, self._steps, self._iters,
                use_split=self._use_split_dyn,
                prox_anchor=(self._anchor if self._method == "fedprox"
                             else None))
        for lora_n, n in zip(locals_, ready):
            fault = self._sample_fault(n, states[n].dispatches)
            dur = self._round_seconds(n, self._use_split_dyn, self._steps,
                                      k, states[n].rounds_run)
            f_n = self.churn.finish_time(n, t, dur)
            if self.pop is not None:
                self.pop.pin(n)
            states[n].dispatch(t, f_n, version_k, states[n].rounds_run)
            if fault is not None and fault.kind == "crash":
                t_c = t + fault.at_frac * max(f_n - t, 0.0)
                queue.push(Event(t_c, CRASH, n, k))
            else:
                if fault is not None and fault.kind == "corrupt":
                    lora_n = corrupt_update(theta_k, lora_n, fault)
                queue.push(Event(f_n, ARRIVAL, n, k,
                                 payload=(lora_n, loss_map[n], fault)))
            self.trace.log(t, DISPATCH, n, k, version=version_k)


SCHEDULERS = {"sync": SyncScheduler, "deadline": DeadlineScheduler,
              "async": AsyncScheduler}
