"""Event-trace recorder: an append-only log of simulator events.

The trace is the runtime's audit surface: determinism tests assert two
runs with the same seed+config produce *identical* traces, and the
time-to-accuracy benchmark mines it for per-policy round/straggler
statistics.  Records are plain tuples so equality is exact.

``of_kind``/``count`` are backed by a per-kind index maintained on
``log`` (and rebuilt when ``records`` is assigned wholesale, e.g. on
checkpoint resume), so mining a long trace is O(matches) instead of a
full scan per query.  The index holds the *same* tuple objects as
``records`` — equality and ordering semantics are unchanged.

When telemetry is enabled (:mod:`repro.telemetry`), every record also
increments a ``runtime.events{kind=...}`` counter — the metrics surface
is bridged from the trace itself, so the two can never disagree.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

from repro import telemetry as _tm

Record = Tuple[float, str, int, int, Tuple]


class EventTrace:
    def __init__(self) -> None:
        self._records: List[Record] = []
        self._by_kind: Dict[str, List[Record]] = {}

    @property
    def records(self) -> List[Record]:
        return self._records

    @records.setter
    def records(self, recs: List[Record]) -> None:
        # wholesale replacement (checkpoint resume): rebuild the index
        self._records = recs
        by_kind: Dict[str, List[Record]] = {}
        for r in recs:
            by_kind.setdefault(r[1], []).append(r)
        self._by_kind = by_kind

    def log(self, time: float, kind: str, client: int = -1, edge: int = -1,
            **info: Any) -> None:
        # info flattened to a sorted tuple of (key, value) pairs so records
        # are hashable/comparable and insertion-order independent
        packed = tuple(sorted((k, _freeze(v)) for k, v in info.items()))
        rec = (float(time), kind, int(client), int(edge), packed)
        self._records.append(rec)
        self._by_kind.setdefault(kind, []).append(rec)
        if _tm.enabled():
            _tm.inc("runtime.events", 1, kind=kind)

    # -- queries -----------------------------------------------------------
    def of_kind(self, kind: str) -> List[Record]:
        return list(self._by_kind.get(kind, ()))

    def count(self, kind: str) -> int:
        return len(self._by_kind.get(kind, ()))

    def end_time(self) -> float:
        return self._records[-1][0] if self._records else 0.0

    def summary(self) -> Dict[str, int]:
        return {kind: len(rs) for kind, rs in self._by_kind.items() if rs}

    def __len__(self) -> int:
        return len(self._records)

    def __eq__(self, other: object) -> bool:
        return (isinstance(other, EventTrace)
                and self._records == other._records)


def _freeze(v: Any):
    """Make a value hashable/comparable for trace records."""
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, float):
        return round(v, 9)       # exact same arithmetic -> exact same round
    return v
