"""Event-trace recorder: an append-only log of simulator events.

The trace is the runtime's audit surface: determinism tests assert two
runs with the same seed+config produce *identical* traces, and the
time-to-accuracy benchmark mines it for per-policy round/straggler
statistics.  Records are plain tuples so equality is exact.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

Record = Tuple[float, str, int, int, Tuple]


class EventTrace:
    def __init__(self) -> None:
        self.records: List[Record] = []

    def log(self, time: float, kind: str, client: int = -1, edge: int = -1,
            **info: Any) -> None:
        # info flattened to a sorted tuple of (key, value) pairs so records
        # are hashable/comparable and insertion-order independent
        packed = tuple(sorted((k, _freeze(v)) for k, v in info.items()))
        self.records.append((float(time), kind, int(client), int(edge),
                             packed))

    # -- queries -----------------------------------------------------------
    def of_kind(self, kind: str) -> List[Record]:
        return [r for r in self.records if r[1] == kind]

    def count(self, kind: str) -> int:
        return len(self.of_kind(kind))

    def end_time(self) -> float:
        return self.records[-1][0] if self.records else 0.0

    def summary(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.records:
            out[r[1]] = out.get(r[1], 0) + 1
        return out

    def __len__(self) -> int:
        return len(self.records)

    def __eq__(self, other: object) -> bool:
        return (isinstance(other, EventTrace)
                and self.records == other.records)


def _freeze(v: Any):
    """Make a value hashable/comparable for trace records."""
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, float):
        return round(v, 9)       # exact same arithmetic -> exact same round
    return v
