"""EdgeRuntime: the entry point tying a Federation to a scheduler policy.

Builds the wall-clock cost model from the federation's *real* artifacts
(its ``ArchConfig``, ``Topology``, ``SketchPlan`` and LoRA tree — via
:func:`repro.core.comm_model.comm_config_from`), owns the availability
trace and the event-trace recorder, and hands control to the policy's
scheduler.  Usage::

    from repro.runtime import RuntimeConfig
    fed = Federation(FedConfig(constrained_frac=0.3))
    hist = fed.run("elsa", runtime=RuntimeConfig(policy="deadline"))
    hist["time"]       # simulated seconds per recorded round
    hist["trace"]      # EventTrace of dispatch/arrival/agg events

A mesh-sharded federation (``Federation(..., mesh=...)``) works
unchanged under every scheduler: each policy's ready-set dispatches
route through ``Federation.group_steps`` into the batched engine, which
shards the stacked client axis across the mesh — cohort bucket padding
(to shard-multiple ladder sizes) keeps the deadline/async policies'
varying ready sets on a bounded set of compiled executables.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.comm_model import comm_config_from
from repro.federation.topology import ChurnTrace, FaultTrace, always_on
from repro.runtime.cost import (DOWNLINK_RATIO_DEFAULT, EDGE_FLOPS_DEFAULT,
                                ClientCostModel)
from repro.runtime.trace import EventTrace

POLICIES = ("sync", "deadline", "async")


@dataclasses.dataclass
class RuntimeConfig:
    """Scheduler policy + knobs of the wall-clock simulation."""
    policy: str = "sync"
    # deadline policy: edge aggregates whoever reported within this many
    # seconds of the edge round start; None derives it from the given
    # quantile of the population's estimated round times.
    deadline_s: Optional[float] = None
    deadline_quantile: float = 0.6
    # weight multiplier per edge round of lateness for carried-over
    # straggler updates (1.0 = no discount)
    straggler_discount: float = 0.5
    # async policy: edge mixes an arrival in with weight
    # alpha / (1 + staleness)^decay, staleness in edge-model versions
    async_alpha: float = 0.6
    staleness_decay: float = 0.5
    # async cloud fusion period; None -> t_rounds x median estimated
    # client round time (the sync cadence without stragglers)
    cloud_period_s: Optional[float] = None
    # availability model; None -> every client always on
    churn: Optional[ChurnTrace] = None
    # fault-injection schedule (crash/drop/dup/corrupt per dispatch);
    # None -> no faults (see repro.federation.topology.FaultTrace)
    faults: Optional[FaultTrace] = None
    # cost-model knobs
    edge_flops: float = EDGE_FLOPS_DEFAULT
    backhaul_bytes_per_s: float = 1.25e9    # edge<->cloud (10 Gbps)
    downlink_ratio: float = DOWNLINK_RATIO_DEFAULT  # downlink/uplink bw
    jitter_sigma: float = 0.0               # lognormal compute jitter
    max_sim_s: float = float("inf")         # hard stop for the event loop

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown runtime policy {self.policy!r}; "
                             f"expected one of {POLICIES}")


class EdgeRuntime:
    """Event-driven executor for one :class:`Federation`."""

    def __init__(self, federation, config: Optional[RuntimeConfig] = None):
        self.federation = federation
        self.config = config or RuntimeConfig()
        fc = federation.fed
        self.comm = comm_config_from(federation.cfg, fc,
                                     plan=(federation.plan
                                           if fc.use_channel else None),
                                     lora=federation.lora0)
        self.cost = ClientCostModel(
            federation.cfg, federation.topo, self.comm,
            batch_size=fc.batch_size, num_classes=fc.num_classes,
            edge_flops=self.config.edge_flops,
            downlink_ratio=self.config.downlink_ratio,
            jitter_sigma=self.config.jitter_sigma, seed=fc.seed)
        self.churn = self.config.churn or always_on(fc.n_clients)
        self.backhaul_s = self.comm.lora_bytes \
            / max(self.config.backhaul_bytes_per_s, 1e-9)
        self.trace = EventTrace()

    def run(self, method: str = "elsa", *, global_rounds: int = 10,
            steps_per_round: int = 4, eval_every: int = 1,
            log: bool = False, checkpoint=None,
            resume_from: Optional[str] = None, population=None) -> Dict:
        from repro.runtime.schedulers import SCHEDULERS
        if (checkpoint is not None or resume_from is not None) \
                and self.config.policy != "sync":
            # deadline/async carry in-flight event-queue state across
            # rounds; only the barrier-synchronous policy snapshots at a
            # round boundary where the full state is in the checkpoint
            raise ValueError("checkpoint/resume is supported on the "
                             "'sync' runtime policy only, not "
                             f"{self.config.policy!r}")
        # registry-backed population (docs/population.md): every policy
        # samples a per-round (sync/deadline) or per-fusion-window
        # (async) cohort of registered ids into the client slots
        self.federation._bind_population(population)
        scheduler = SCHEDULERS[self.config.policy](self)
        history = scheduler.run(method, global_rounds, steps_per_round,
                                eval_every, log, checkpoint=checkpoint,
                                resume_from=resume_from)
        history["policy"] = self.config.policy
        history["trace"] = self.trace
        return history
