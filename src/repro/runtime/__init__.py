"""Event-driven edge runtime: wall-clock simulation of hierarchical FL.

The round-synchronous :meth:`repro.federation.simulation.Federation.run`
loop has no notion of time — every client finishes every round instantly.
This subsystem assigns each client a simulated wall-clock cost per local
round (compute from ``Topology.capacity`` + the client's ``Split`` FLOPs,
uplink/downlink from the Eq. 22–24 comm model fed by the *actual*
``SketchPlan``/LoRA shapes), models availability churn, and schedules edge
rounds under pluggable policies:

- ``sync``      — barrier per edge round; reproduces today's semantics
                  (bit-identical history on the batched backend);
- ``deadline``  — the edge aggregates whoever reported by a per-round
                  deadline; stragglers carry their update into the next
                  aggregation;
- ``async``     — the edge folds arrivals in continuously with
                  staleness-discounted weights; the cloud fuses on a period.

Entry points: ``Federation.run(..., runtime=RuntimeConfig(...))`` or
:class:`EdgeRuntime` directly.  Histories gain a ``time`` axis (simulated
seconds) so accuracy-vs-wall-clock curves exist.
"""
from repro.runtime.cost import ClientCostModel, RoundCost
from repro.runtime.events import Event, EventQueue
from repro.runtime.runtime import EdgeRuntime, RuntimeConfig
from repro.runtime.trace import EventTrace

__all__ = ["ClientCostModel", "RoundCost", "EdgeRuntime", "Event",
           "EventQueue", "EventTrace", "RuntimeConfig"]
