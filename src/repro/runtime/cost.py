"""Wall-clock cost model for one client-edge local round.

Compute time follows the roofline module's 6·N·D training convention
(:mod:`repro.analysis.roofline`): a local step costs ``6 × N_client ×
tokens`` FLOPs, where ``N_client`` counts only the parameters the client
actually executes under its tripartite :class:`~repro.core.split_training.
Split` — Part 1 (``p`` blocks) + Part 3 (``o`` blocks + pooler/head); the
edge runs the ``q`` middle blocks on server-class capacity.  Divided by
``Topology.capacity[n]`` (FLOP/s) this yields compute seconds.

Communication time prices the sketched boundary activations with the
Eq. 22–24 model (:mod:`repro.core.comm_model`) fed by a ``CommConfig``
derived from the *actual* model config and ``SketchPlan``
(``comm_config_from``), plus the per-edge-round LoRA upload and the
propagation latency of the client-edge link.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.comm_model import CommConfig, client_comm_time
from repro.core.split_training import Split
from repro.models.bert import bert_specs
from repro.models.params import is_spec

EDGE_FLOPS_DEFAULT = 5e12    # server-class edge accelerator (FLOP/s)


def _spec_params(tree) -> float:
    import jax.tree_util as jtu
    return float(sum(np.prod(s.shape)
                     for s in jtu.tree_leaves(tree, is_leaf=is_spec)))


@dataclasses.dataclass(frozen=True)
class RoundCost:
    """Cost breakdown of one local round (seconds)."""
    compute_s: float
    comm_s: float
    latency_s: float

    @property
    def total_s(self) -> float:
        return self.compute_s + self.comm_s + self.latency_s


class ClientCostModel:
    """Maps (client, Split, steps) -> simulated seconds.

    Deterministic: costs depend only on the topology, the model shapes,
    and optional per-(client, round) lognormal jitter drawn from a seeded
    generator — identical across runs with the same config.
    """

    def __init__(self, cfg, topo, comm: CommConfig, *, batch_size: int,
                 num_classes: int = 2,
                 edge_flops: float = EDGE_FLOPS_DEFAULT,
                 jitter_sigma: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.topo = topo
        self.comm = comm
        self.batch_size = int(batch_size)
        self.edge_flops = float(edge_flops)
        self.jitter_sigma = float(jitter_sigma)
        self._seed = seed

        specs = bert_specs(cfg, num_classes)
        n_layers = cfg.num_layers
        self.block_params = (_spec_params(specs["frozen"]["blocks"])
                             + _spec_params(specs["lora"]["blocks"])
                             ) / n_layers
        self.head_params = (_spec_params(specs["lora"]["pooler"])
                            + _spec_params(specs["lora"]["head"]))

    # -- FLOPs (6ND convention) -------------------------------------------
    def client_flops_per_step(self, split: Split) -> float:
        n = (split.p + split.o) * self.block_params + self.head_params
        tokens = self.batch_size * self.comm.seq_len
        return 6.0 * n * tokens

    def edge_flops_per_step(self, split: Split) -> float:
        return 6.0 * split.q * self.block_params \
            * self.batch_size * self.comm.seq_len

    # -- per-round cost ----------------------------------------------------
    def round_cost(self, client: int, split: Split, steps: int,
                   edge: Optional[int] = None,
                   round_idx: int = 0) -> RoundCost:
        """One local round of ``steps`` gradient steps for ``client``.

        ``edge=None`` (or an out-of-range escalation key like ``-1``)
        prices the nearest edge's link latency.
        """
        cap = float(self.topo.capacity[client])
        compute = steps * (self.client_flops_per_step(split) / cap
                           + self.edge_flops_per_step(split)
                           / self.edge_flops)
        if self.jitter_sigma > 0.0:
            rng = np.random.default_rng(
                (self._seed, client, round_idx))
            compute *= float(rng.lognormal(0.0, self.jitter_sigma))

        # boundary activations for the whole round (Eq. 23 with t=1 and
        # the real examples-per-round count) + the LoRA upload to the edge
        per_round = dataclasses.replace(self.comm, t_rounds=1)
        bw = float(self.topo.bandwidth[client])
        comm = client_comm_time(per_round, self.batch_size * steps, bw)
        comm += self.comm.lora_bytes / max(bw, 1e-9)

        k = edge if edge is not None and 0 <= edge < \
            self.topo.latency.shape[1] else int(
                np.argmin(self.topo.latency[client]))
        lat = 2.0 * float(self.topo.latency[client, k]) / 1e3
        return RoundCost(compute, comm, lat)

    def estimate_population(self, splits: Dict[int, Split], steps: int,
                            edge_of: Optional[Dict[int, int]] = None
                            ) -> Dict[int, float]:
        """Total seconds per client for one local round (no churn) —
        used by schedulers to auto-derive deadlines / cloud periods."""
        return {n: self.round_cost(
                    n, s, steps,
                    edge_of.get(n) if edge_of else None).total_s
                for n, s in splits.items()}
