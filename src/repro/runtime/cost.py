"""Wall-clock cost model for one client-edge local round.

Compute time follows the roofline module's 6·N·D training convention
(:mod:`repro.analysis.roofline`): a local step costs ``6 × N_client ×
tokens`` FLOPs, where ``N_client`` counts only the parameters the client
actually executes under its tripartite :class:`~repro.core.split_training.
Split` — Part 1 (``p`` blocks) + Part 3 (``o`` blocks + the task head);
the edge runs the ``q`` middle blocks on server-class capacity.  The
per-block and head parameter counts come from the model's
:class:`~repro.models.split_api.SplitModel` adapter
(``block_param_count`` / ``head_param_count``), so any registered
architecture is priced from its real Spec shapes.  Divided by
``Topology.capacity[n]`` (FLOP/s) this yields compute seconds.

Communication time prices, per local round:

- the sketched boundary activations with the Eq. 22–24 model
  (:mod:`repro.core.comm_model`) fed by a ``CommConfig`` derived from the
  *actual* model config and ``SketchPlan`` (``comm_config_from``);
- the per-edge-round LoRA upload (uplink);
- the cloud→client model broadcast (downlink) at round start — the
  fused LoRA the client must fetch before training; downlink bandwidth
  is ``downlink_ratio ×`` the client's uplink (access links are
  asymmetric; ratio 1.0 recovers a symmetric link);
- the propagation latency of the client-edge link.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.comm_model import CommConfig, client_comm_time
from repro.core.split_training import Split
from repro.models.split_api import split_model_for

EDGE_FLOPS_DEFAULT = 5e12    # server-class edge accelerator (FLOP/s)
DOWNLINK_RATIO_DEFAULT = 4.0  # downlink/uplink bandwidth asymmetry


@dataclasses.dataclass(frozen=True)
class RoundCost:
    """Cost breakdown of one local round (seconds + wire bytes)."""
    compute_s: float
    comm_s: float          # uplink: boundary activations + LoRA upload
    latency_s: float
    downlink_s: float = 0.0  # cloud->client model broadcast
    # wire volume behind the comm terms (telemetry's bytes breakdown;
    # informational — the seconds above stay the costs of record)
    uplink_bytes: float = 0.0
    downlink_bytes: float = 0.0

    @property
    def total_s(self) -> float:
        return self.compute_s + self.comm_s + self.latency_s \
            + self.downlink_s


class ClientCostModel:
    """Maps (client, Split, steps) -> simulated seconds.

    Deterministic: costs depend only on the topology, the model shapes,
    and optional per-(client, round) lognormal jitter drawn from a seeded
    generator — identical across runs with the same config.
    """

    def __init__(self, cfg, topo, comm: CommConfig, *, batch_size: int,
                 num_classes: int = 2,
                 edge_flops: float = EDGE_FLOPS_DEFAULT,
                 downlink_ratio: float = DOWNLINK_RATIO_DEFAULT,
                 jitter_sigma: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.topo = topo
        self.comm = comm
        self.batch_size = int(batch_size)
        self.edge_flops = float(edge_flops)
        self.downlink_ratio = float(downlink_ratio)
        self.jitter_sigma = float(jitter_sigma)
        self._seed = seed

        model = split_model_for(cfg)
        self.block_params = model.block_param_count(num_classes)
        self.head_params = model.head_param_count(num_classes)

    # -- FLOPs (6ND convention) -------------------------------------------
    def client_flops_per_step(self, split: Split) -> float:
        n = (split.p + split.o) * self.block_params + self.head_params
        tokens = self.batch_size * self.comm.seq_len
        return 6.0 * n * tokens

    def edge_flops_per_step(self, split: Split) -> float:
        return 6.0 * split.q * self.block_params \
            * self.batch_size * self.comm.seq_len

    # -- per-round cost ----------------------------------------------------
    def round_cost(self, client: int, split: Split, steps: int,
                   edge: Optional[int] = None,
                   round_idx: int = 0) -> RoundCost:
        """One local round of ``steps`` gradient steps for ``client``.

        ``edge=None`` (or an out-of-range escalation key like ``-1``)
        prices the nearest edge's link latency.
        """
        cap = float(self.topo.capacity[client])
        compute = steps * (self.client_flops_per_step(split) / cap
                           + self.edge_flops_per_step(split)
                           / self.edge_flops)
        if self.jitter_sigma > 0.0:
            rng = np.random.default_rng(
                (self._seed, client, round_idx))
            compute *= float(rng.lognormal(0.0, self.jitter_sigma))

        # boundary activations for the whole round (Eq. 23 with t=1 and
        # the real examples-per-round count) + the LoRA upload to the edge
        per_round = dataclasses.replace(self.comm, t_rounds=1)
        bw = float(self.topo.bandwidth[client])
        activ_s = client_comm_time(per_round, self.batch_size * steps, bw)
        comm = activ_s + self.comm.lora_bytes / max(bw, 1e-9)
        up_bytes = activ_s * bw + self.comm.lora_bytes
        # cloud->client model broadcast before training starts
        downlink = self.comm.lora_bytes / max(bw * self.downlink_ratio,
                                              1e-9)

        k = edge if edge is not None and 0 <= edge < \
            self.topo.latency.shape[1] else int(
                np.argmin(self.topo.latency[client]))
        lat = 2.0 * float(self.topo.latency[client, k]) / 1e3
        return RoundCost(compute, comm, lat, downlink,
                         uplink_bytes=up_bytes,
                         downlink_bytes=float(self.comm.lora_bytes))

    def estimate_population(self, splits: Dict[int, Split], steps: int,
                            edge_of: Optional[Dict[int, int]] = None
                            ) -> Dict[int, float]:
        """Total seconds per client for one local round (no churn) —
        used by schedulers to auto-derive deadlines / cloud periods."""
        return {n: self.round_cost(
                    n, s, steps,
                    edge_of.get(n) if edge_of else None).total_s
                for n, s in splits.items()}
