"""Per-client runtime state machine.

States::

    IDLE ──dispatch──▶ TRAINING ──complete──▶ REPORTED ──collect──▶ IDLE
                          │                                    (result folded
                          └── churn pauses stretch busy_until ──┘  into an agg)

The deadline and async schedulers consult this to know who is eligible
for dispatch (``IDLE`` and online), who is a straggler (``TRAINING`` past
a deadline), and which edge-model version an arriving update was trained
from (its staleness).  Transitions assert legality so scheduler bugs
surface as errors, not silent double-dispatches.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

IDLE = "idle"
TRAINING = "training"
REPORTED = "reported"


@dataclasses.dataclass
class ClientRuntimeState:
    client: int
    state: str = IDLE
    dispatch_time: float = 0.0
    busy_until: float = 0.0       # churn-adjusted completion time
    base_version: int = 0         # edge-model version trained from
    base_round: int = 0           # edge round index at dispatch
    result: Optional[Any] = None  # (lora, loss) parked on completion
    rounds_run: int = 0
    dispatches: int = 0           # fault-schedule index: counts every
                                  # dispatch, crashed ones included (a
                                  # crash never completes, so indexing
                                  # faults by rounds_run would replay
                                  # the same crash forever)

    def dispatch(self, t: float, finish: float, version: int,
                 round_idx: int) -> None:
        assert self.state == IDLE, \
            f"client {self.client}: dispatch while {self.state}"
        assert finish >= t
        self.state = TRAINING
        self.dispatch_time = t
        self.busy_until = finish
        self.base_version = version
        self.base_round = round_idx
        self.result = None
        self.dispatches += 1

    def crash(self) -> None:
        """Fault injection: the in-flight round is lost (not paused —
        that's churn); the client idles and can be re-dispatched."""
        assert self.state == TRAINING, \
            f"client {self.client}: crash while {self.state}"
        self.state = IDLE
        self.result = None

    def complete(self, result: Any) -> None:
        assert self.state == TRAINING, \
            f"client {self.client}: complete while {self.state}"
        self.state = REPORTED
        self.result = result
        self.rounds_run += 1

    def collect(self) -> Any:
        """Fold the parked update into an aggregation; client idles."""
        assert self.state == REPORTED, \
            f"client {self.client}: collect while {self.state}"
        out, self.result = self.result, None
        self.state = IDLE
        return out

    @property
    def idle(self) -> bool:
        return self.state == IDLE

    def staleness(self, version: int) -> int:
        """Edge-model versions elapsed since this client was dispatched."""
        return max(0, version - self.base_version)
