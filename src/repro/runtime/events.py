"""Discrete-event core: a deterministic time-ordered event queue.

Events are ordered by ``(time, seq)`` — ``seq`` is a monotonically
increasing insertion counter, so simultaneous events pop in insertion
order and the simulation is fully deterministic for a given schedule of
pushes (no hash/id tie-breaks).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Iterator, Optional

# event kinds used by the schedulers
DISPATCH = "dispatch"          # client handed a model, starts local round
ARRIVAL = "arrival"            # client's update reaches its edge
EDGE_AGG = "edge_agg"          # edge aggregates its received updates
CLOUD_AGG = "cloud_agg"        # cloud fuses edge models
OFFLINE = "offline"            # client unavailable at dispatch time
REJOIN = "rejoin"              # client back online, eligible again
EVAL = "eval"                  # server-side evaluation snapshot
# fault-injection kinds (see repro.federation.topology.FaultTrace)
CRASH = "crash"                # client died mid-round, work lost
DROP = "drop"                  # finished update never reached the edge
DUP = "dup"                    # uplink delivered twice
CORRUPT = "corrupt"            # update arrived mangled (NaN/flip/scale)


@dataclasses.dataclass(frozen=True)
class Event:
    time: float
    kind: str
    client: int = -1           # -1: not client-scoped
    edge: int = -1             # -1: cloud / not edge-scoped
    payload: Any = None        # scheduler-private (model refs, versions…)


class EventQueue:
    """Min-heap of :class:`Event` with deterministic FIFO tie-breaking."""

    def __init__(self) -> None:
        self._heap: list = []
        self._seq = 0

    def push(self, ev: Event) -> None:
        heapq.heappush(self._heap, (ev.time, self._seq, ev))
        self._seq += 1

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[2]

    def peek(self) -> Optional[Event]:
        return self._heap[0][2] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def drain_until(self, t: float) -> Iterator[Event]:
        """Pop every event with ``time <= t`` in order."""
        while self._heap and self._heap[0][0] <= t:
            yield self.pop()
