"""JSONL export + merged run summary for :class:`~repro.telemetry.
collector.Telemetry` (schema in docs/observability.md).

A telemetry file is line-delimited JSON:

- line 1: ``{"type": "meta", "schema": 1, "meta": {...}}``;
- one ``{"type": "round", "round": g, "counters": {delta}, "gauges":
  {...}, "spans": [...], "sim_time_s": t}`` per closed round (counters
  are per-round *deltas*; gauges are the values at the boundary);
- last line: ``{"type": "summary", ...}`` — the cumulative counters,
  final gauges, full histogram states, and per-span-name wall/sim
  aggregates of the whole run (:func:`summarize`).

Rationale for JSONL over one JSON blob: a killed run still leaves every
completed round parseable, and ``analysis/telemetry_report.py`` can
stream arbitrarily long runs.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

from repro.telemetry.collector import SCHEMA_VERSION, Telemetry


def summarize(tel: Telemetry) -> Dict[str, Any]:
    """Merged run summary: cumulative metrics + per-span aggregates."""
    spans: Dict[str, Dict[str, float]] = {}
    for rec in tel.rounds + [{"spans": tel._spans}]:
        for s in rec.get("spans", ()):
            agg = spans.setdefault(s["name"],
                                   {"count": 0, "wall_s": 0.0, "sim_s": 0.0})
            agg["count"] += 1
            agg["wall_s"] += s.get("dur_s", 0.0)
            agg["sim_s"] += float(s.get("attrs", {}).get("sim_s", 0.0))
    return {
        "type": "summary", "schema": SCHEMA_VERSION,
        "meta": dict(tel.meta),
        "rounds": len(tel.rounds),
        "counters": dict(tel.counters),
        "gauges": dict(tel.gauges),
        "histograms": {k: h.state() for k, h in tel.histograms.items()},
        "spans": spans,
    }


def export_jsonl(tel: Telemetry, path: str) -> str:
    """Write meta + per-round records + summary; returns ``path``."""
    tel.flush_pending()
    d = os.path.dirname(os.path.abspath(path))
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        f.write(json.dumps({"type": "meta", "schema": SCHEMA_VERSION,
                            "meta": dict(tel.meta)}, sort_keys=True) + "\n")
        for rec in tel.rounds:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
        f.write(json.dumps(summarize(tel), sort_keys=True) + "\n")
    return path


def read_jsonl(path: str) -> Dict[str, Any]:
    """Parse a telemetry file into ``{"meta", "rounds", "summary"}``.

    Tolerates a missing summary line (killed run): the summary is then
    rebuilt from the round records' deltas.
    """
    meta: Dict[str, Any] = {}
    rounds = []
    summary = None
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.get("type")
            if kind == "meta":
                meta = rec
            elif kind == "round":
                rounds.append(rec)
            elif kind == "summary":
                summary = rec
    if summary is None:
        counters: Dict[str, float] = {}
        spans: Dict[str, Dict[str, float]] = {}
        gauges: Dict[str, Any] = {}
        for rec in rounds:
            for k, v in rec.get("counters", {}).items():
                counters[k] = counters.get(k, 0.0) + v
            gauges.update(rec.get("gauges", {}))
            for s in rec.get("spans", ()):
                agg = spans.setdefault(s["name"], {"count": 0,
                                                   "wall_s": 0.0,
                                                   "sim_s": 0.0})
                agg["count"] += 1
                agg["wall_s"] += s.get("dur_s", 0.0)
                agg["sim_s"] += float(s.get("attrs", {}).get("sim_s", 0.0))
        summary = {"type": "summary", "schema": meta.get("schema", 0),
                   "meta": meta.get("meta", {}), "rounds": len(rounds),
                   "counters": counters, "gauges": gauges,
                   "histograms": {}, "spans": spans}
    return {"meta": meta, "rounds": rounds, "summary": summary}
