"""Process-wide metrics registry + round-lifecycle span tracer.

One :class:`Telemetry` instance collects everything a run emits
(docs/observability.md):

- **counters** — monotone totals (``inc``), e.g. runtime events bridged
  one-for-one from :class:`~repro.runtime.trace.EventTrace`, engine jit
  compiles, screening verdicts, simulated comm bytes;
- **gauges** — last-value samples (``set_gauge``), e.g. trust-ledger
  snapshots, compile-cache sizes, donated-buffer placement;
- **histograms** — fixed-bucket distributions (``observe``), e.g. the
  engine's per-dispatch wall time split by compiled-vs-cached, serving
  request latency, checkpoint save/restore latency;
- **spans** — wall-clock timed sections of the round lifecycle
  (``dispatch -> local_steps -> uplink -> edge_agg -> cloud_agg ->
  eval``), recorded via the ``with telemetry.span(name, ...)`` context
  manager or, for phases that only exist on the simulated clock,
  ``record_span(name, dur_s=0, sim_s=...)``.

``end_round(g)`` closes one round: pending spans plus the counter
*deltas* since the previous round boundary become one per-round record,
exportable as JSONL (:mod:`repro.telemetry.export`).  Metric identity is
``name{label=value,...}`` with labels sorted, so keys are stable across
runs and mergeable across processes.

The module is intentionally free of any ``repro`` import (instrumented
layers import *it*, never the reverse) and never touches device arrays:
recording is pure host-side bookkeeping, so an enabled run computes
bit-identical histories to a disabled one.
"""
from __future__ import annotations

import bisect
import time
from typing import Any, Dict, List, Optional, Sequence

#: JSONL schema version written by the exporter.
SCHEMA_VERSION = 1

#: Default histogram bucket upper bounds (seconds, log-spaced).  Values
#: above the last bound land in the +inf overflow bucket.
DEFAULT_TIME_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2,
                        0.1, 0.3, 1.0, 3.0, 10.0, 30.0)


def flat_key(name: str, labels: Dict[str, Any]) -> str:
    """``name{k=v,...}`` with sorted labels; bare ``name`` unlabeled."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Histogram:
    """Fixed-bucket histogram: counts per bucket + sum/count/min/max."""

    __slots__ = ("buckets", "counts", "sum", "count", "min", "max")

    def __init__(self, buckets: Sequence[float] = DEFAULT_TIME_BUCKETS):
        bs = tuple(float(b) for b in buckets)
        if list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError(f"histogram buckets must be strictly "
                             f"increasing, got {bs}")
        self.buckets = bs
        self.counts = [0] * (len(bs) + 1)   # +1: overflow bucket
        self.sum = 0.0
        self.count = 0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        v = float(value)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1
        self.min = v if v < self.min else self.min
        self.max = v if v > self.max else self.max

    def state(self) -> Dict[str, Any]:
        return {"buckets": list(self.buckets), "counts": list(self.counts),
                "sum": self.sum, "count": self.count,
                "min": (None if self.count == 0 else self.min),
                "max": (None if self.count == 0 else self.max)}


class _SpanCtx:
    """Context manager recording one wall-timed span on exit."""

    __slots__ = ("_tel", "name", "attrs", "_t0")

    def __init__(self, tel: "Telemetry", name: str, attrs: Dict[str, Any]):
        self._tel = tel
        self.name = name
        self.attrs = attrs

    def set(self, **attrs: Any) -> None:
        """Attach attributes discovered while the span is open."""
        self.attrs.update(attrs)

    def __enter__(self) -> "_SpanCtx":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self._tel.record_span(self.name,
                              dur_s=time.perf_counter() - self._t0,
                              **self.attrs)
        return False


class NullSpan:
    """Shared no-op span handed out while telemetry is disabled."""

    __slots__ = ()

    def __enter__(self) -> "NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs: Any) -> None:
        pass


NULL_SPAN = NullSpan()


class Telemetry:
    """One run's worth of counters/gauges/histograms/spans.

    ``sink`` (any :class:`repro.telemetry.sinks.Sink`) receives the meta
    record now and every round record as it closes — streaming
    observability for long runs.  ``retain_rounds`` bounds the in-memory
    ``rounds`` window (oldest records are dropped once a sink — or
    nobody — needs them); both default off, leaving the historical
    in-memory behavior untouched.
    """

    def __init__(self, meta: Optional[Dict[str, Any]] = None,
                 sink=None, retain_rounds: Optional[int] = None):
        if retain_rounds is not None and retain_rounds < 0:
            raise ValueError(f"retain_rounds must be >= 0, got "
                             f"{retain_rounds}")
        self.meta = dict(meta or {})
        self.sink = sink
        self.retain_rounds = retain_rounds
        self.started = time.perf_counter()
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}
        self.rounds: List[Dict[str, Any]] = []
        self._spans: List[Dict[str, Any]] = []   # pending (open round)
        self._round_base: Dict[str, float] = {}  # counters at last boundary
        if sink is not None:
            sink.emit_meta({"type": "meta", "schema": SCHEMA_VERSION,
                            "meta": dict(self.meta)})

    # -- recording ---------------------------------------------------------
    def inc(self, name: str, value: float = 1.0, **labels: Any) -> None:
        k = flat_key(name, labels)
        self.counters[k] = self.counters.get(k, 0.0) + float(value)

    def set_gauge(self, name: str, value: float, **labels: Any) -> None:
        self.gauges[flat_key(name, labels)] = float(value)

    def observe(self, name: str, value: float,
                buckets: Optional[Sequence[float]] = None,
                **labels: Any) -> None:
        k = flat_key(name, labels)
        h = self.histograms.get(k)
        if h is None:
            h = self.histograms[k] = Histogram(buckets
                                               or DEFAULT_TIME_BUCKETS)
        h.observe(value)

    def span(self, name: str, **attrs: Any) -> _SpanCtx:
        return _SpanCtx(self, name, attrs)

    def record_span(self, name: str, dur_s: float = 0.0,
                    **attrs: Any) -> None:
        """Record a pre-measured span (simulated-clock phases pass their
        duration via ``sim_s=`` attrs and keep ``dur_s`` at ~0)."""
        rec: Dict[str, Any] = {"name": name, "dur_s": float(dur_s)}
        if attrs:
            rec["attrs"] = attrs
        self._spans.append(rec)

    # -- reads -------------------------------------------------------------
    def counter(self, name: str, **labels: Any) -> float:
        return self.counters.get(flat_key(name, labels), 0.0)

    def gauge(self, name: str, **labels: Any) -> Optional[float]:
        return self.gauges.get(flat_key(name, labels))

    def counters_by_name(self, name: str) -> Dict[str, float]:
        """All ``name{...}`` series: flat key -> cumulative value."""
        prefix = name + "{"
        return {k: v for k, v in self.counters.items()
                if k == name or k.startswith(prefix)}

    # -- round lifecycle ---------------------------------------------------
    def end_round(self, round_idx: Optional[int],
                  sim_time_s: Optional[float] = None) -> Dict[str, Any]:
        """Close one round: counter deltas since the previous boundary +
        the spans recorded inside it become one JSONL-able record.
        ``round_idx=None`` marks an unnumbered trailing record (the
        ``flush_pending`` fold) — a streaming sink must see the same
        ``round: null`` the exporter writes."""
        delta = {k: v - self._round_base.get(k, 0.0)
                 for k, v in self.counters.items()
                 if v != self._round_base.get(k, 0.0)}
        self._round_base = dict(self.counters)
        rec: Dict[str, Any] = {"type": "round",
                               "round": (None if round_idx is None
                                         else int(round_idx)),
                               "counters": delta,
                               "gauges": dict(self.gauges),
                               "spans": self._spans}
        if sim_time_s is not None:
            rec["sim_time_s"] = float(sim_time_s)
        if self.sink is not None:
            self.sink.emit_round(rec)
        self.rounds.append(rec)
        if self.retain_rounds is not None \
                and len(self.rounds) > self.retain_rounds:
            del self.rounds[:len(self.rounds) - self.retain_rounds]
        self._spans = []
        return rec

    def flush_pending(self) -> None:
        """Fold any spans/counter deltas recorded since the last round
        boundary into a final unnumbered round record (callers that
        never call ``end_round`` — e.g. the serving engine — still
        export everything)."""
        if self._spans or any(
                v != self._round_base.get(k, 0.0)
                for k, v in self.counters.items()):
            self.end_round(None)
