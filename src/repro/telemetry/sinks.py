"""Streaming telemetry sinks (docs/observability.md).

The default :class:`~repro.telemetry.collector.Telemetry` keeps every
round record in memory and writes one JSONL file at export time.  That
is the wrong shape for long federations (a 10^4-round run holds every
span of every round until the end) and for watching a live run.  A
*sink* receives each record the moment it exists:

- ``emit_meta(rec)`` once, when the collector is created;
- ``emit_round(rec)`` at every ``end_round`` boundary;
- ``close(summary)`` when the session ends (the run summary, if the
  caller computed one).

Attach one via ``telemetry.enable(sink=...)`` or
``telemetry.session(sink=...)``; pair it with ``retain_rounds=`` to
bound the collector's in-memory window.  With no sink attached nothing
changes — the in-memory path stays bit-identical to before sinks
existed.

:class:`JsonlSink` writes the same line-delimited schema as
:func:`repro.telemetry.export.export_jsonl` (meta line, round records,
summary line), flushed per round so a killed run leaves every completed
round on disk, with optional size-based rotation: when the live file
would exceed ``rotate_bytes`` it is renamed to ``<path>.<k>`` (k
increasing with age) and a fresh file re-opens at ``path`` starting
with a copy of the meta line — every part parses standalone with
:func:`repro.telemetry.export.read_jsonl`.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional


class Sink:
    """Base streaming sink: every hook is a no-op; subclasses override
    what they need.  Hooks must never raise into the round loop —
    telemetry failures must not kill a federation (JsonlSink relies on
    the filesystem; callers choosing fancier transports should catch
    their own errors)."""

    def emit_meta(self, rec: Dict[str, Any]) -> None:
        pass

    def emit_round(self, rec: Dict[str, Any]) -> None:
        pass

    def close(self, summary: Optional[Dict[str, Any]] = None) -> None:
        pass


class JsonlSink(Sink):
    """Append-per-round JSONL file sink with optional size rotation.

    ``rotate_bytes=0`` (default) never rotates.  ``append=True`` opens
    an existing file for appending instead of truncating — useful for
    resumed runs sharing one telemetry file (the new session's meta
    line marks the boundary).
    """

    def __init__(self, path: str, *, rotate_bytes: int = 0,
                 append: bool = False):
        if rotate_bytes < 0:
            raise ValueError(f"rotate_bytes must be >= 0, got "
                             f"{rotate_bytes}")
        self.path = path
        self.rotate_bytes = int(rotate_bytes)
        self.parts = 0                       # rotated-out file count
        self._meta_line: Optional[str] = None
        d = os.path.dirname(os.path.abspath(path))
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "a" if append else "w")

    # -- hooks ---------------------------------------------------------------
    def emit_meta(self, rec: Dict[str, Any]) -> None:
        self._meta_line = json.dumps(rec, sort_keys=True)
        self._write(self._meta_line)

    def emit_round(self, rec: Dict[str, Any]) -> None:
        self._write(json.dumps(rec, sort_keys=True))

    def close(self, summary: Optional[Dict[str, Any]] = None) -> None:
        if self._f.closed:
            return
        if summary is not None:
            self._write(json.dumps(summary, sort_keys=True))
        self._f.close()

    # -- mechanics -----------------------------------------------------------
    def _write(self, line: str) -> None:
        if self.rotate_bytes and self._f.tell() > 0 \
                and self._f.tell() + len(line) + 1 > self.rotate_bytes:
            self._rotate()
        self._f.write(line + "\n")
        self._f.flush()

    def _rotate(self) -> None:
        """Roll the live file out to ``<path>.<k>`` and re-open fresh,
        re-stamping the meta line so the new part parses standalone."""
        self._f.close()
        self.parts += 1
        os.replace(self.path, f"{self.path}.{self.parts}")
        self._f = open(self.path, "w")
        if self._meta_line is not None:
            self._f.write(self._meta_line + "\n")

    def rotated_paths(self) -> List[str]:
        """Rolled-out part paths, oldest first (the live file is
        ``self.path``)."""
        return [f"{self.path}.{k}" for k in range(1, self.parts + 1)]


def finalize_sink(tel) -> None:
    """Flush a collector's trailing partial round into its sink and
    close the sink with the run summary.  No-op without a sink."""
    sink = getattr(tel, "sink", None)
    if sink is None:
        return
    from repro.telemetry.export import summarize
    tel.flush_pending()
    sink.close(summarize(tel))
