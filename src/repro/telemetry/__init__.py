"""Federation telemetry: structured metrics + round-phase tracing.

Process-wide observability with a hard zero-overhead-when-disabled
contract (docs/observability.md): every module-level helper here checks
one ``None`` and returns, and the instrumented layers never allocate,
sync, or branch on telemetry state in a way that can perturb the math —
a telemetry-enabled run produces bit-identical histories and event
traces to a disabled one (``tests/test_telemetry.py``).

Usage::

    from repro import telemetry as tm

    tm.enable(meta={"bench": "fed_round"})
    fed.run("elsa", global_rounds=4)             # layers self-instrument
    tm.export("runs/telemetry.jsonl")            # per-round JSONL+summary
    tm.disable()

or scoped::

    with tm.session(jsonl="runs/telemetry.jsonl"):
        fed.run(...)

Instrumented layers (all no-ops while disabled):

- ``repro.runtime`` — every :meth:`EventTrace.log` record bridges to a
  ``runtime.events{kind=...}`` counter (metrics can never disagree with
  the determinism trace), schedulers record round-lifecycle spans
  (``dispatch``/``local_steps``/``uplink``/``edge_agg``/``cloud_agg``/
  ``eval``) and per-phase simulated seconds + comm bytes;
- ``repro.federation.engine`` — jit compiles per (split, bucket),
  compile-vs-cached dispatch wall time, cohort/phantom sizes,
  donated-buffer placement;
- ``repro.core.screening`` — verdict counters by reason + trust-ledger
  gauge snapshots;
- ``repro.checkpoint`` — save/restore latency and snapshot bytes;
- ``repro.serving`` — request-latency histogram, adapter hot-swaps.
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Sequence

from repro.telemetry.collector import (DEFAULT_TIME_BUCKETS, NULL_SPAN,
                                       SCHEMA_VERSION, Histogram, NullSpan,
                                       Telemetry, flat_key)
from repro.telemetry.export import export_jsonl, read_jsonl, summarize
from repro.telemetry.sinks import JsonlSink, Sink, finalize_sink

__all__ = [
    "DEFAULT_TIME_BUCKETS", "SCHEMA_VERSION", "Histogram", "NullSpan",
    "Telemetry", "flat_key", "export_jsonl", "read_jsonl", "summarize",
    "Sink", "JsonlSink", "finalize_sink",
    "enabled", "enable", "disable", "get", "inc", "set_gauge", "observe",
    "span", "record_span", "end_round", "export", "summary", "session",
]

_active: Optional[Telemetry] = None


def enabled() -> bool:
    return _active is not None


def get() -> Optional[Telemetry]:
    """The live collector, or None while disabled."""
    return _active


def enable(meta: Optional[Dict[str, Any]] = None, sink: Optional[Sink] = None,
           retain_rounds: Optional[int] = None) -> Telemetry:
    """Start a fresh collector (replacing any previous one).

    ``sink`` streams every round record as it closes
    (:mod:`repro.telemetry.sinks`); ``retain_rounds`` bounds the
    in-memory round window.  Both default off — the in-memory path is
    unchanged.
    """
    global _active
    _active = Telemetry(meta, sink=sink, retain_rounds=retain_rounds)
    return _active


def disable() -> None:
    """Stop collecting; a streaming sink is flushed (trailing partial
    round + run summary) and closed on the way out."""
    global _active
    if _active is not None:
        finalize_sink(_active)
    _active = None


# -- forwarding helpers (each is one None-check when disabled) -------------

def inc(name: str, value: float = 1.0, **labels: Any) -> None:
    t = _active
    if t is not None:
        t.inc(name, value, **labels)


def set_gauge(name: str, value: float, **labels: Any) -> None:
    t = _active
    if t is not None:
        t.set_gauge(name, value, **labels)


def observe(name: str, value: float,
            buckets: Optional[Sequence[float]] = None,
            **labels: Any) -> None:
    t = _active
    if t is not None:
        t.observe(name, value, buckets=buckets, **labels)


def span(name: str, **attrs: Any):
    t = _active
    return t.span(name, **attrs) if t is not None else NULL_SPAN


def record_span(name: str, dur_s: float = 0.0, **attrs: Any) -> None:
    t = _active
    if t is not None:
        t.record_span(name, dur_s=dur_s, **attrs)


def end_round(round_idx: int, sim_time_s: Optional[float] = None) -> None:
    t = _active
    if t is not None:
        t.end_round(round_idx, sim_time_s=sim_time_s)


def export(path: str) -> Optional[str]:
    """Write the live collector's JSONL; None while disabled."""
    t = _active
    return export_jsonl(t, path) if t is not None else None


def summary() -> Optional[Dict[str, Any]]:
    t = _active
    return summarize(t) if t is not None else None


@contextlib.contextmanager
def session(meta: Optional[Dict[str, Any]] = None,
            jsonl: Optional[str] = None, sink: Optional[Sink] = None,
            retain_rounds: Optional[int] = None):
    """Enable for a block; export to ``jsonl`` (if given) on the way
    out, then restore the previous collector (sessions nest).  A
    ``sink`` streams rounds live instead and is flushed + closed on
    exit (``retain_rounds`` bounds the in-memory window meanwhile)."""
    global _active
    prev = _active
    tel = Telemetry(meta, sink=sink, retain_rounds=retain_rounds)
    _active = tel
    try:
        yield tel
    finally:
        if jsonl is not None:
            export_jsonl(tel, jsonl)
        finalize_sink(tel)
        _active = prev
