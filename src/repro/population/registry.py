"""Array-resident per-client state for the full registered population.

One :class:`ClientRegistry` row per registered client, held as
preallocated numpy columns (structure-of-arrays, not dict-of-objects):

==================  =========  ==============================================
column              dtype      meaning
==================  =========  ==============================================
``trust``           float64    screening trust EMA (:mod:`repro.core.
                               screening`); seeded 1.0, synced with the
                               slot-level ``TrustLedger`` every round
``staleness_ema``   float64    EMA of rounds-between-participations
``last_round``      int64      last global round the client trained (-1 never)
``participations``  int64      completed participations
``draws``           int64      batch-stream cursor (``CountingIterator``
                               count), so an evicted iterator rebuilds
                               bit-exactly
``edge``            int32      edge group of the last assignment (-1 none)
``cluster``         int32      clustering-time cluster id (-1 none)
``data_seed``       uint64     per-client data-synthesis stream key
``n_examples``      int64      local dataset size (0 until first seen)
``avail_cursor``    int64      churn-trace interval cursor
                               (:class:`~repro.population.sampler.
                               AvailabilityCursors`)
``screen_passes``   int64      screening verdicts credited to this
                               identity that passed (attribution follows
                               the pinned dispatch-time id, never the
                               slot's current occupant)
``screen_fails``    int64      screening verdicts credited to this
                               identity that failed
==================  =========  ==============================================

The LoRA adapter-delta column is a ``(registered, adapter_dim)`` matrix
stored as fixed-size row-block shards allocated on first touch: scalar
columns are O(registered) and tiny, while adapter memory grows with the
set of clients that actually trained (~ cohort x rounds), never with the
registered population — at 10^5 clients x ~83k adapter floats an eager
matrix would be ~33 GB; lazily it is a few shards.

Gather/scatter are the only access paths (``tests/test_population.py``
pins the round-trip invariant: a scatter touches exactly its rows and
leaves every other row bitwise intact).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

_MASK64 = np.uint64(0xFFFFFFFFFFFFFFFF)

#: (name, dtype, fill) for every eager scalar column.
SCALAR_COLUMNS = (
    ("trust", np.float64, 1.0),
    ("staleness_ema", np.float64, 0.0),
    ("last_round", np.int64, -1),
    ("participations", np.int64, 0),
    ("draws", np.int64, 0),
    ("edge", np.int32, -1),
    ("cluster", np.int32, -1),
    ("data_seed", np.uint64, 0),
    ("n_examples", np.int64, 0),
    ("avail_cursor", np.int64, 0),
    ("screen_passes", np.int64, 0),
    ("screen_fails", np.int64, 0),
)


def mix64(x: np.ndarray, salt: int = 0) -> np.ndarray:
    """Vectorized splitmix64 finalizer: a stable 64-bit stream key per
    client id, so data-seed columns fill in one vectorized pass instead
    of 10^5 ``SeedSequence`` spawns."""
    with np.errstate(over="ignore"):
        z = (np.asarray(x, np.uint64) + np.uint64(salt)
             + np.uint64(0x9E3779B97F4A7C15)) & _MASK64
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9) \
            & _MASK64
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB) \
            & _MASK64
    return z ^ (z >> np.uint64(31))


class ClientRegistry:
    """Preallocated per-client state columns + lazily-sharded adapter
    deltas for ``registered`` clients."""

    def __init__(self, registered: int, *, adapter_dim: int = 0,
                 shard_rows: int = 256, adapter_dtype: str = "float32",
                 seed: int = 0):
        if registered < 1:
            raise ValueError(f"registered must be >= 1, got {registered}")
        if shard_rows < 1:
            raise ValueError(f"shard_rows must be >= 1, got {shard_rows}")
        self.registered = int(registered)
        self.adapter_dim = int(adapter_dim)
        self.shard_rows = int(shard_rows)
        self.adapter_dtype = np.dtype(adapter_dtype)
        self.seed = int(seed)
        self.columns: Dict[str, np.ndarray] = {
            name: np.full(registered, fill, dtype=dt)
            for name, dt, fill in SCALAR_COLUMNS}
        self.columns["data_seed"] = mix64(np.arange(registered), salt=seed)
        n_shards = -(-registered // self.shard_rows)
        self._adapter_shards: List[Optional[np.ndarray]] = [None] * n_shards

    def __getattr__(self, name: str) -> np.ndarray:
        cols = self.__dict__.get("columns")
        if cols is not None and name in cols:
            return cols[name]
        raise AttributeError(name)

    # -- scalar columns -----------------------------------------------------
    def _check_ids(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        if ids.size and (ids.min() < 0 or ids.max() >= self.registered):
            raise IndexError(f"client ids out of range [0, "
                             f"{self.registered}): {ids.min()}..{ids.max()}")
        return ids

    def gather(self, ids: Sequence[int],
               columns: Optional[Sequence[str]] = None
               ) -> Dict[str, np.ndarray]:
        """Copies of the requested columns at ``ids`` (cohort-sized)."""
        ids = self._check_ids(ids)
        names = columns if columns is not None else self.columns.keys()
        return {name: self.columns[name][ids].copy() for name in names}

    def scatter(self, ids: Sequence[int], **values: np.ndarray) -> None:
        """Write cohort-sized vectors back into their registry rows."""
        ids = self._check_ids(ids)
        for name, v in values.items():
            col = self.columns[name]
            col[ids] = np.asarray(v).astype(col.dtype, copy=False)

    # -- adapter-delta column -----------------------------------------------
    def _shard_of(self, i: int) -> np.ndarray:
        s = self._adapter_shards[i]
        if s is None:
            rows = min(self.shard_rows,
                       self.registered - i * self.shard_rows)
            s = np.zeros((rows, self.adapter_dim), self.adapter_dtype)
            self._adapter_shards[i] = s
        return s

    def has_adapter_shard(self, i: int) -> bool:
        return self._adapter_shards[i] is not None

    def gather_adapters(self, ids: Sequence[int]) -> np.ndarray:
        """(len(ids), adapter_dim) deltas; untouched rows read as zero
        without allocating their shard."""
        ids = self._check_ids(ids)
        out = np.zeros((len(ids), self.adapter_dim), self.adapter_dtype)
        for j, cid in enumerate(ids):
            i = int(cid) // self.shard_rows
            s = self._adapter_shards[i]
            if s is not None:
                out[j] = s[int(cid) - i * self.shard_rows]
        return out

    def scatter_adapters(self, ids: Sequence[int],
                         deltas: np.ndarray) -> None:
        ids = self._check_ids(ids)
        deltas = np.asarray(deltas)
        if deltas.shape != (len(ids), self.adapter_dim):
            raise ValueError(f"adapter deltas shape {deltas.shape} != "
                             f"({len(ids)}, {self.adapter_dim})")
        for j, cid in enumerate(ids):
            i = int(cid) // self.shard_rows
            self._shard_of(i)[int(cid) - i * self.shard_rows] = \
                deltas[j].astype(self.adapter_dtype, copy=False)

    # -- accounting -----------------------------------------------------------
    @property
    def allocated_shards(self) -> int:
        return sum(s is not None for s in self._adapter_shards)

    @property
    def n_shards(self) -> int:
        return len(self._adapter_shards)

    @property
    def nbytes(self) -> int:
        """Resident bytes: every scalar column + allocated adapter
        shards only (the lazy-allocation contract the population bench
        reports as registry memory)."""
        n = sum(c.nbytes for c in self.columns.values())
        n += sum(s.nbytes for s in self._adapter_shards if s is not None)
        return n

    # -- checkpoint plumbing --------------------------------------------------
    def state(self) -> Dict:
        return {
            "registered": self.registered,
            "adapter_dim": self.adapter_dim,
            "shard_rows": self.shard_rows,
            "adapter_dtype": self.adapter_dtype.name,
            "seed": self.seed,
            "columns": dict(self.columns),
            # int-keyed pairs, wire-stable like checkpoint groups/draws
            "adapter_shards": [[i, s] for i, s in
                               enumerate(self._adapter_shards)
                               if s is not None],
        }

    def load_state(self, state: Dict) -> None:
        for field in ("registered", "adapter_dim", "shard_rows"):
            if int(state[field]) != getattr(self, field):
                raise ValueError(
                    f"registry {field} mismatch: checkpoint has "
                    f"{state[field]}, this registry {getattr(self, field)}")
        for name, col in self.columns.items():
            if name not in state["columns"]:
                # column added after the checkpoint was written: keep
                # its freshly-initialized fill so pre-upgrade snapshots
                # stay loadable
                continue
            self.columns[name] = np.asarray(state["columns"][name],
                                            col.dtype).copy()
        self._adapter_shards = [None] * self.n_shards
        for i, s in state["adapter_shards"]:
            self._adapter_shards[int(i)] = np.asarray(
                s, self.adapter_dtype).copy()
