"""PopulationRuntime: binds a registry + sampler to a live Federation.

The federation's compiled machinery is slot-indexed (``n_clients``
slots: topology, splits, buckets, channels, trust ledger).  This binding
streams registered client *identities* through those slots, one cohort
per round:

- ``begin_round(g)`` samples the cohort, installs the slot->id map, and
  gathers registry trust into the slot-level
  :class:`~repro.core.screening.TrustLedger`;
- during the round, the federation sees the occupants transparently:
  :class:`_IterProxy` resolves ``iters[slot]`` to the occupant's seeded
  batch stream (LRU-cached; evicted streams persist their cursor in the
  registry ``draws`` column and fast-forward bit-exactly on return) and
  ``Federation.client_weight`` reads the occupant's example count;
- ``note_updates`` scatters the trained LoRA deltas (vs the dispatch
  model) into the registry's sharded adapter column;
- ``end_round(g)`` scatters trust/staleness/participation/cursors back.

Client data: ids below ``n_clients`` reuse the federation's materialized
datasets **by construction** — the legacy generator draws every client
from one shared sequential RNG, so client ``n``'s data depends on the
draws of clients ``< n`` and can never be regenerated per-id; ids at or
beyond ``n_clients`` synthesize lazily from the registry's per-id
``data_seed`` stream (Dirichlet class mix + the same token sampler) and
live in an LRU.  With ``registered == n_clients`` every id hits the
legacy datasets and the identity cohort draws no RNG, which is what
makes the binding bit-inert there.

Privacy channels and trust follow the *identity*, not the slot:

- :meth:`channel_for_slot` resolves a slot to its occupant and serves
  that identity's SS-OP channel from a bounded LRU.  The semantic basis
  ``U`` (SVD of the reference model's probe embeddings) is shared and
  computed once; the per-identity secret rotation ``V_n`` is seeded by
  ``Hash(salt || id)`` (Eq. 18), so two identities streaming through the
  same slot get distinct rotations and an evicted identity's channel
  regenerates bit-exactly on return — the same cursor-free determinism
  the data-stream LRU gets from ``fast_forward``;
- :meth:`record_trust` / :meth:`trust_weight` attribute screening
  verdicts to an identity: in-cohort ids go through the slot-level
  :class:`~repro.core.screening.TrustLedger` (and mirror into the
  registry ``trust`` column immediately), while a straggler whose slot
  was re-assigned applies the same EMA directly to its registry row —
  the slot's new occupant is never credited or blamed for work it did
  not do.  ``screen_passes`` / ``screen_fails`` count per-identity
  verdicts for audit.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro import telemetry as tm
from repro.core.split_training import Channel
from repro.data.pipeline import CountingIterator, infinite_batches
from repro.data.synthetic import ClientData, make_task, sample_examples
from repro.population.registry import ClientRegistry
from repro.population.sampler import CohortSampler, PopulationConfig


class _IterProxy:
    """``iters[slot]`` -> the current occupant's batch stream."""

    __slots__ = ("_pop",)

    def __init__(self, pop: "PopulationRuntime"):
        self._pop = pop

    def __getitem__(self, slot: int) -> CountingIterator:
        return self._pop.iter_for(int(self._pop.slot_to_id[slot]))


class _IdentityLedger:
    """Identity-keyed facade over the population's trust state, shaped
    like a :class:`~repro.core.screening.TrustLedger` so
    :func:`~repro.core.screening.screen_updates` /
    ``screen_and_aggregate`` run unchanged with client *ids* in place of
    slot indices: ``record`` routes through
    :meth:`PopulationRuntime.record_trust` (ledger for in-cohort ids,
    registry EMA for stragglers) and ``scores`` is the registry ``trust``
    column itself — population-sized, always current because in-cohort
    records mirror into it immediately."""

    __slots__ = ("_pop",)

    def __init__(self, pop: "PopulationRuntime"):
        self._pop = pop

    @property
    def beta(self) -> float:
        return self._pop.federation.trust_ledger.beta

    @property
    def scores(self) -> np.ndarray:
        return self._pop.registry.trust

    def record(self, cid: int, passed: bool) -> None:
        self._pop.record_trust(cid, passed)

    def weight(self, cid: int) -> float:
        return self._pop.trust_weight(cid)


class PopulationRuntime:
    """One federation's registry-backed population (docs/population.md)."""

    def __init__(self, federation, cfg: PopulationConfig):
        fed = federation.fed
        if cfg.registered < fed.n_clients:
            raise ValueError(
                f"registered population ({cfg.registered}) must be >= the "
                f"federation's slot count (n_clients={fed.n_clients})")
        if cfg.cohort is not None and cfg.cohort != fed.n_clients:
            raise ValueError(
                f"cohort must equal the federation's n_clients slot count "
                f"({fed.n_clients}); got {cfg.cohort} — resize n_clients "
                "to change the per-round cohort")
        self.federation = federation
        self.cfg = cfg
        self.cohort = fed.n_clients
        adapter_dim = 0
        if cfg.store_adapters:
            import jax
            adapter_dim = int(sum(
                np.prod(leaf.shape)
                for leaf in jax.tree_util.tree_leaves(federation.lora0)))
        self.adapter_dim = adapter_dim
        self.registry = ClientRegistry(
            cfg.registered, adapter_dim=adapter_dim,
            shard_rows=cfg.shard_rows, adapter_dtype=cfg.adapter_dtype,
            seed=fed.seed)
        self.sampler = CohortSampler(self.registry, cfg)
        self.slot_to_id = np.arange(self.cohort, dtype=np.int64)
        self.iters = _IterProxy(self)
        cap = cfg.data_cache or max(4 * self.cohort, 64)
        self._cache_cap = max(cap, self.cohort)
        self._data: "OrderedDict[int, ClientData]" = OrderedDict()
        self._iters: "OrderedDict[int, CountingIterator]" = OrderedDict()
        self._class_p = None           # synthesized-task unigrams, lazy
        self._inflight: Dict[int, int] = {}     # slot -> pinned id
        self._round_ids: Optional[np.ndarray] = None
        self._id_to_slot: Dict[int, int] = {
            i: i for i in range(self.cohort)}
        # identity-keyed SS-OP channel LRU (shared U basis, per-id V_n;
        # evictions regenerate bit-exactly from the identity's seed)
        self._channel_cap = max(cfg.channel_cache or self._cache_cap,
                                self.cohort)
        self._channels: "OrderedDict[int, Channel]" = OrderedDict()
        self._chan_hits = 0
        self._chan_misses = 0
        self._chan_evictions = 0
        self.ledger_view = _IdentityLedger(self)

    # -- per-client data ------------------------------------------------------
    def data_for(self, cid: int) -> ClientData:
        fed = self.federation
        if cid < fed.fed.n_clients:
            return fed.data[cid]
        d = self._data.get(cid)
        if d is None:
            d = self._synthesize(cid)
            self._data[cid] = d
            while len(self._data) > self._cache_cap:
                self._data.popitem(last=False)
        else:
            self._data.move_to_end(cid)
        return d

    def _synthesize(self, cid: int) -> ClientData:
        """Per-id dataset from the registry data-seed stream: its own
        Dirichlet class mix + the shared class-conditional unigrams, so
        synthesized clients match the §IV.A heterogeneity model without
        the legacy generator's sequential cross-client RNG coupling."""
        fed = self.federation
        task = fed.task
        if self._class_p is None:
            self._class_p = make_task(task)
        rng = np.random.default_rng(int(self.registry.data_seed[cid]))
        props = rng.dirichlet([fed.fed.alpha] * task.num_classes)
        n_ex = max(8, fed.fed.total_examples // fed.fed.n_clients)
        labels = rng.choice(task.num_classes, size=n_ex, p=props)
        tokens = sample_examples(task, self._class_p, labels, rng)
        return ClientData(tokens=tokens, labels=labels.astype(np.int32))

    def iter_for(self, cid: int) -> CountingIterator:
        it = self._iters.get(cid)
        if it is None:
            fed = self.federation
            d = self.data_for(cid)
            it = CountingIterator(infinite_batches(
                d.tokens, d.labels, fed.fed.batch_size,
                seed=fed.fed.seed + 100 + cid))
            it.fast_forward(int(self.registry.draws[cid]))
            self._iters[cid] = it
            while len(self._iters) > self._cache_cap:
                old_cid, old_it = self._iters.popitem(last=False)
                self.registry.draws[old_cid] = old_it.count
        else:
            self._iters.move_to_end(cid)
        return it

    def slot_weight(self, slot: int) -> int:
        """FedAvg weight of the slot's current occupant."""
        return len(self.data_for(int(self.slot_to_id[slot])).tokens)

    # -- identity-keyed SS-OP channels ----------------------------------------
    def channel_for_slot(self, slot: int) -> Channel:
        """The SS-OP channel of the slot's *current occupant* — the
        privacy rotation travels with the identity, never the slot."""
        return self.channel_for_id(int(self.slot_to_id[int(slot)]))

    def channel_for_id(self, cid: int) -> Channel:
        fed = self.federation
        if not fed.fed.use_channel:
            return Channel(None, None)
        cid = int(cid)
        ch = self._channels.get(cid)
        if ch is None:
            self._chan_misses += 1
            ch = fed._build_identity_channel(cid)
            self._channels[cid] = ch
            while len(self._channels) > self._channel_cap:
                self._channels.popitem(last=False)
                self._chan_evictions += 1
        else:
            self._chan_hits += 1
            self._channels.move_to_end(cid)
        return ch

    def adopt_channel(self, cid: int, channel: Channel) -> None:
        """Install a deserialized channel (checkpoint restore) under its
        identity, honoring the LRU bound."""
        self._channels[int(cid)] = channel
        self._channels.move_to_end(int(cid))
        while len(self._channels) > self._channel_cap:
            self._channels.popitem(last=False)

    # -- identity-keyed trust attribution -------------------------------------
    def record_trust(self, cid: int, passed: bool) -> None:
        """Credit a screening verdict to the identity that trained the
        update.  An in-cohort id records through the slot-level ledger
        (same EMA floats as the legacy path, mirrored into the registry
        immediately so reads stay current); a straggler whose slot was
        handed to someone else applies the EMA to its own registry row —
        the new occupant's trust is untouched."""
        cid = int(cid)
        reg = self.registry
        slot = self._id_to_slot.get(cid)
        if slot is not None:
            ledger = self.federation.trust_ledger
            ledger.record(slot, passed)
            reg.trust[cid] = ledger.scores[slot]
        else:
            b = self.federation.trust_ledger.beta
            reg.trust[cid] = b * reg.trust[cid] \
                + (1.0 - b) * (1.0 if passed else 0.0)
        if passed:
            reg.screen_passes[cid] += 1
        else:
            reg.screen_fails[cid] += 1

    def trust_weight(self, cid: int) -> float:
        """The identity's current trust EMA (registry column; in-cohort
        mirrors keep it bit-equal to the slot ledger)."""
        return float(self.registry.trust[int(cid)])

    # -- round lifecycle ------------------------------------------------------
    def after_assign(self, groups: Dict[int, List[int]]) -> None:
        """Seed registry columns from the clustering phase: the
        bootstrap cohort (ids 0..n_clients-1 in identity slots) carries
        its fingerprint-clustered edge assignment and the ledger's
        clustering-time trust into the registry."""
        fed = self.federation
        n = fed.fed.n_clients
        boot = np.arange(n, dtype=np.int64)
        self.registry.scatter(boot, trust=fed.trust_ledger.scores[:n])
        for k, members in groups.items():
            if members:
                m = np.asarray(members, np.int64)
                self.registry.scatter(m, edge=np.full(len(m), k, np.int32),
                                      cluster=np.full(len(m), k, np.int32))

    def begin_round(self, round_idx: int,
                    t: Optional[float] = None) -> np.ndarray:
        """Sample the cohort, install the slot->id map, load trust."""
        ids = self.sampler.sample(round_idx, self.cohort, t=t)
        self.slot_to_id = ids
        self._round_ids = ids
        self._id_to_slot = {int(c): s for s, c in enumerate(ids)}
        # registry trust -> slot ledger (float64 copies round-trip
        # exactly, so the identity cohort is bit-inert)
        self.federation.trust_ledger.scores = \
            self.registry.trust[ids].copy()
        if tm.enabled():
            tm.set_gauge("population.registered", self.registry.registered)
            tm.set_gauge("population.eligible", self.sampler.last_eligible)
            tm.set_gauge("population.sampled", len(ids))
            tm.set_gauge("population.registry_bytes", self.registry.nbytes)
        return ids

    def note_updates(self, slots: Sequence[int], trees: Sequence,
                     base, ids: Optional[Sequence[int]] = None) -> None:
        """Scatter trained LoRA deltas (vs the dispatch model ``base``)
        into the registry's sharded adapter column."""
        if self.adapter_dim == 0 or not len(trees):
            return
        if ids is None:
            ids = [int(self.slot_to_id[s]) for s in slots]
        base_flat = self._flatten(base)
        mat = np.stack([self._flatten(t) - base_flat for t in trees])
        self.registry.scatter_adapters(np.asarray(ids, np.int64), mat)

    @staticmethod
    def _flatten(tree) -> np.ndarray:
        import jax
        return np.concatenate([
            np.asarray(leaf, np.float64).ravel()
            for leaf in jax.tree_util.tree_leaves(tree)])

    def end_round(self, round_idx: int) -> None:
        """Scatter the round's outcomes back into the registry."""
        ids = self._round_ids
        if ids is None:
            return
        reg = self.registry
        ledger = self.federation.trust_ledger
        reg.scatter(ids, trust=ledger.scores[:len(ids)])
        prev = reg.last_round[ids]
        age = np.where(prev >= 0, round_idx - prev, 0).astype(np.float64)
        b = self.cfg.staleness_beta
        reg.staleness_ema[ids] = b * reg.staleness_ema[ids] + (1 - b) * age
        reg.last_round[ids] = round_idx
        reg.participations[ids] += 1
        for cid in ids:
            cid = int(cid)
            it = self._iters.get(cid)
            if it is not None:
                reg.draws[cid] = it.count
            d = self._data.get(cid)
            if d is not None or cid < self.federation.fed.n_clients:
                reg.n_examples[cid] = len(self.data_for(cid).tokens)
        if tm.enabled():
            tm.set_gauge("population.registry_bytes", reg.nbytes)
            tm.set_gauge("population.adapter_shards",
                         reg.allocated_shards)
            tm.set_gauge("population.channel_cache_size",
                         len(self._channels))
            tm.set_gauge("population.channel_cache_hits", self._chan_hits)
            tm.set_gauge("population.channel_cache_misses",
                         self._chan_misses)
            tm.set_gauge("population.channel_cache_evictions",
                         self._chan_evictions)

    # -- in-flight identity (deadline/async stragglers) -----------------------
    def pin(self, slot: int) -> int:
        """Record the slot's occupant at dispatch time, so a straggler
        completing after a cohort swap still writes back under the
        identity that trained it."""
        cid = int(self.slot_to_id[slot])
        self._inflight[slot] = cid
        return cid

    def pinned(self, slot: int) -> int:
        return self._inflight.get(int(slot), int(self.slot_to_id[slot]))

    def sync_draws(self) -> None:
        """Persist every live iterator cursor into the registry (called
        before checkpointing)."""
        for cid, it in self._iters.items():
            self.registry.draws[cid] = it.count

    # -- checkpoint plumbing --------------------------------------------------
    def state(self) -> Dict:
        self.sync_draws()
        # cached identity channels ride along so a resume serves the
        # exact same SS-OP bases without re-probing (an absent entry
        # would regenerate bit-exactly anyway — the seeds live in the
        # identity, not the snapshot)
        chans = [[int(cid),
                  None if ch.ssop is None else
                  {"u": ch.ssop.u, "v": ch.ssop.v,
                   "w": ch.ssop.w, "w_inv": ch.ssop.w_inv}]
                 for cid, ch in self._channels.items()]
        return {
            "registered": self.cfg.registered,
            "seed": self.cfg.seed,
            "strategy": self.cfg.strategy,
            "registry": self.registry.state(),
            "slot_to_id": np.asarray(self.slot_to_id, np.int64),
            "channels": chans,
        }

    def load_state(self, state: Dict) -> None:
        from repro.core.ssop import SSOP
        for field in ("registered", "seed", "strategy"):
            if state[field] != getattr(self.cfg, field):
                raise ValueError(
                    f"population {field} mismatch: checkpoint has "
                    f"{state[field]!r}, this run {getattr(self.cfg, field)!r}")
        self.registry.load_state(state["registry"])
        self.slot_to_id = np.asarray(state["slot_to_id"], np.int64).copy()
        self._id_to_slot = {int(c): s
                            for s, c in enumerate(self.slot_to_id)}
        self._data.clear()
        self._iters.clear()
        self._inflight.clear()
        self._round_ids = None
        self._channels.clear()
        plan = self.federation.plan \
            if self.federation.fed.use_channel else None
        # "channels" is absent from pre-identity-keying snapshots; those
        # carried slot-keyed channels in the top-level checkpoint section
        # instead (restore_run adopts them, slot == identity at the
        # profile time they were built)
        for cid, ss in state.get("channels", []):
            ssop = None if ss is None else SSOP(
                u=ss["u"], v=ss["v"], w=ss["w"], w_inv=ss["w_inv"])
            self.adopt_channel(int(cid), Channel(ssop, plan))
