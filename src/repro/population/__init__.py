"""Population-scale client registry (docs/population.md).

The federation machinery (:mod:`repro.federation`, :mod:`repro.runtime`)
operates on a fixed set of ``FedConfig.n_clients`` *slots*: topology,
splits, engine buckets, edge groups, channels and the trust ledger are
all slot-indexed arrays of that size.  This package decouples the
*registered population* from those slots:

- :class:`~repro.population.registry.ClientRegistry` holds every
  registered client's durable state (LoRA adapter delta, trust /
  staleness EMAs, cluster + edge assignment, availability cursor,
  data-seed, batch-stream cursor) in preallocated array columns —
  no per-client Python objects, so 10^5–10^6 clients cost megabytes;
- :class:`~repro.population.sampler.CohortSampler` materializes each
  round's active cohort as a gather of registry rows into the slots and
  writes round outcomes back via scatter, so per-round cost scales with
  the cohort size, not the population size;
- :class:`~repro.population.runtime.PopulationRuntime` binds the two to
  a live :class:`~repro.federation.simulation.Federation`: it swaps
  per-round client identity under the slots (data, batch streams,
  FedAvg weights, trust) while every compiled path stays untouched.

``Federation.run(..., population=PopulationConfig(registered=N))`` (and
the sync/deadline/async runtime schedulers) activate it; with
``registered == n_clients`` the binding is bit-inert — the identity
cohort draws no RNG and the history matches the legacy dict path
exactly (golden-anchored in ``tests/test_population.py``).
"""
from repro.population.registry import ClientRegistry
from repro.population.sampler import (AvailabilityCursors, CohortSampler,
                                      PopulationConfig)
from repro.population.runtime import PopulationRuntime

__all__ = ["ClientRegistry", "CohortSampler", "AvailabilityCursors",
           "PopulationConfig", "PopulationRuntime"]
