"""Cohort sampling + population-scale availability cursors.

:class:`CohortSampler` picks each round's active client ids from the
registry.  Sampling is stateless per round — the round-``g`` cohort is a
pure function of ``(PopulationConfig.seed, g)`` via
``np.random.SeedSequence(seed, spawn_key=(g,))``, the same trick the
fault traces use — so schedulers that replay or resume a run re-derive
identical cohorts without threading RNG state.

Two invariants matter for bit-identity with the legacy dict path:

- the **identity fast path**: when every registered client is eligible
  and the cohort is the whole population, the sampler returns
  ``arange(k)`` without touching RNG at all, so a
  ``registered == n_clients`` population run consumes exactly the same
  random streams as a run with no population attached;
- the **uniform fast path** draws via Floyd's O(k) algorithm — cost per
  round scales with the cohort, not the registered population (only the
  eligibility-filtered paths pay one vectorized O(N) mask).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.federation.topology import ChurnTrace

STRATEGIES = ("uniform", "round-robin")


@dataclasses.dataclass
class PopulationConfig:
    """Knobs of the registry-backed population (docs/population.md)."""
    registered: int                    # registered population size (>= the
                                       # federation's n_clients slot count)
    cohort: Optional[int] = None       # active cohort per round; None ->
                                       # the federation's n_clients (the
                                       # only supported value: slots are
                                       # the cohort)
    strategy: str = "uniform"          # "uniform" | "round-robin"
    min_trust: float = 0.0             # eligibility floor on the trust EMA
    seed: int = 0                      # cohort-sampling stream seed
    churn: Optional[ChurnTrace] = None # population-sized availability
                                       # trace; offline clients are not
                                       # sampled (cursor-advanced, O(1)
                                       # amortized per query)
    store_adapters: bool = True        # keep per-client LoRA deltas in the
                                       # registry (off: scalar columns only)
    shard_rows: int = 256              # adapter-column rows per lazy shard
    adapter_dtype: str = "float32"
    staleness_beta: float = 0.8        # staleness-EMA retention
    data_cache: int = 0                # synthesized-client LRU capacity;
                                       # 0 -> max(4 x cohort, 64)
    channel_cache: int = 0             # identity SS-OP channel LRU
                                       # capacity; 0 -> the data-cache
                                       # default (evicted rotations
                                       # regenerate bit-exactly from the
                                       # identity's seed)

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown sampling strategy "
                             f"{self.strategy!r}; expected {STRATEGIES}")
        if self.registered < 1:
            raise ValueError("registered must be >= 1")
        if not 0.0 <= self.staleness_beta <= 1.0:
            raise ValueError("staleness_beta must be in [0, 1]")
        if self.churn is not None \
                and len(self.churn.offline) < self.registered:
            raise ValueError(
                f"population churn trace covers {len(self.churn.offline)} "
                f"clients, need >= registered={self.registered}")


class AvailabilityCursors:
    """Vectorized, cursor-advanced online mask over a
    :class:`~repro.federation.topology.ChurnTrace`.

    The trace's ragged per-client interval lists pad into ``(N, M, 2)``
    matrices once; ``online_mask(t)`` then advances one int64 cursor per
    client past expired intervals and compares the current interval only
    — amortized O(1) per client per query for the monotone timestamps
    schedulers produce (a backwards query resets the cursors and
    re-advances, still correct, just not O(1)).
    """

    def __init__(self, trace: ChurnTrace, n: Optional[int] = None,
                 cursors: Optional[np.ndarray] = None):
        n = len(trace.offline) if n is None else n
        m = max((len(iv) for iv in trace.offline[:n]), default=0)
        self.starts = np.full((n, max(m, 1)), np.inf)
        self.ends = np.full((n, max(m, 1)), np.inf)
        for i, iv in enumerate(trace.offline[:n]):
            if len(iv):
                self.starts[i, :len(iv)] = iv[:, 0]
                self.ends[i, :len(iv)] = iv[:, 1]
        self.cursor = (np.zeros(n, np.int64) if cursors is None
                       else np.asarray(cursors, np.int64).copy())
        self._rows = np.arange(n)
        self._last_t = -np.inf

    def online_mask(self, t: float) -> np.ndarray:
        if t < self._last_t:
            self.cursor[:] = 0
        self._last_t = t
        top = len(self.starts[0]) - 1
        while True:
            e = self.ends[self._rows, self.cursor]
            behind = (e <= t) & (self.cursor < top)
            if not behind.any():
                break
            self.cursor[behind] += 1
        s = self.starts[self._rows, self.cursor]
        e = self.ends[self._rows, self.cursor]
        return ~((s <= t) & (t < e))


def _floyd_sample(rng: np.random.Generator, n: int, k: int) -> np.ndarray:
    """k distinct uniform draws from range(n) in O(k) (Floyd's
    algorithm) — never materializes the population."""
    chosen = set()
    for j in range(n - k, n):
        t = int(rng.integers(0, j + 1))
        chosen.add(j if t in chosen else t)
    return np.fromiter(chosen, np.int64, len(chosen))


class CohortSampler:
    """Materializes each round's active cohort from the registry."""

    def __init__(self, registry, cfg: PopulationConfig):
        self.registry = registry
        self.cfg = cfg
        self.avail = (AvailabilityCursors(cfg.churn, n=registry.registered)
                      if cfg.churn is not None else None)
        self.last_eligible = registry.registered

    def _rng(self, round_idx: int) -> np.random.Generator:
        return np.random.default_rng(np.random.SeedSequence(
            self.cfg.seed, spawn_key=(int(round_idx),)))

    def sample(self, round_idx: int, k: int,
               t: Optional[float] = None) -> np.ndarray:
        """Sorted ids of round ``round_idx``'s cohort (size ``k``)."""
        reg, cfg = self.registry, self.cfg
        n = reg.registered
        if k > n:
            raise ValueError(f"cohort {k} exceeds registered {n}")
        filtered = cfg.min_trust > 0.0 or self.avail is not None
        if not filtered:
            self.last_eligible = n
            if k == n:
                # identity fast path: no RNG consumed -> a population of
                # exactly the slot count is bit-inert vs the legacy path
                return np.arange(n, dtype=np.int64)
            if cfg.strategy == "uniform":
                return np.sort(_floyd_sample(self._rng(round_idx), n, k))
            return self._round_robin(np.arange(n, dtype=np.int64),
                                     round_idx, k)
        # one vectorized O(N) mask per round; everything after is O(k)
        mask = reg.trust >= cfg.min_trust
        if self.avail is not None:
            mask &= self.avail.online_mask(0.0 if t is None else t)
        elig = np.flatnonzero(mask).astype(np.int64)
        self.last_eligible = len(elig)
        if len(elig) < k:
            # not enough eligible clients: top up with the highest-trust
            # ineligible ones so a round never under-fills its slots
            rest = np.flatnonzero(~mask).astype(np.int64)
            order = np.argsort(-reg.trust[rest], kind="stable")
            elig = np.concatenate([elig, rest[order[:k - len(elig)]]])
        if len(elig) == k:
            return np.sort(elig)
        if cfg.strategy == "uniform":
            pick = _floyd_sample(self._rng(round_idx), len(elig), k)
            return np.sort(elig[pick])
        return self._round_robin(np.sort(elig), round_idx, k)

    def _round_robin(self, elig: np.ndarray, round_idx: int,
                     k: int) -> np.ndarray:
        """Deterministic wrap-around coverage: round g takes the slice
        starting at ``(g * k) % len`` — every client trains once per
        ``ceil(len/k)`` rounds."""
        start = (int(round_idx) * k) % len(elig)
        idx = (start + np.arange(k)) % len(elig)
        return np.sort(elig[idx])
