"""End-to-end ELSA federation simulation (Alg. 1) plus FL baselines.

Runs the *real* machinery end to end on a reduced model: behavioral
fingerprinting on a public probe set, trust scoring, latency-aware spectral
clustering, per-client dynamic splits, split training through the
SS-OP∘sketch channel, edge FedAvg, and coherence/trust-weighted cloud
fusion with the Eq. 16 stopping rule.

The harness is model-agnostic: ``FedConfig.model`` names any architecture
registered in :mod:`repro.models.split_api` (the paper's ``"bert-base"``
encoder by default, or a dense causal LM such as ``"llama3-8b"``), and
every phase — warmup, fingerprinting, split training, evaluation —
dispatches through the :class:`~repro.models.split_api.SplitModel`
protocol.

Two execution backends share this harness (``Federation(...,
backend=...)``):

- ``"batched"`` (default): the :mod:`repro.federation.engine` compiled
  path — clients stacked along a leading axis, ``vmap``-ed gradient
  steps, ``lax.scan`` over local steps, one host sync per round;
- ``"reference"``: the original one-client-at-a-time eager loop, kept
  bit-comparable for parity tests and as the benchmark baseline.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry as tm
from repro.core import aggregation as agg
from repro.core import clustering as clus
from repro.core import splitting as split_mod
from repro.core.fingerprint import divergence_matrix, fingerprint
from repro.core.sketch import make_plan
from repro.core.split_training import Channel, Split, split_loss
from repro.core.ssop import make_ssop, make_ssop_from_basis, semantic_subspace
from repro.core.trust import trust_scores
from repro.data.pipeline import infinite_batches
from repro.data.probe import make_probe_set
from repro.data.synthetic import SyntheticTaskConfig, make_federation_data, make_test_set
from repro.federation.engine import (BatchedEngine, is_client_map,
                                     stack_trees)
from repro.federation.topology import make_topology
from repro.models.params import init_tree
from repro.models.split_api import get_split_model
from repro.optim import (SGD, AdamW, FedAdam, FedProx, FedAMS,
                         adapter_head_lr_tree, clip_by_global_norm,
                         fedprox_gradient)


@dataclasses.dataclass
class FedConfig:
    n_clients: int = 20
    n_edges: int = 4
    alpha: float = 0.1                   # Dirichlet concentration
    poisoned: tuple = (3, 8, 12, 17)     # 4 unreliable clients (§IV.A)
    total_examples: int = 4000
    batch_size: int = 16
    t_rounds: int = 2                    # client-edge rounds per global agg
    probe_q: int = 32
    tau_max: float = 200.0
    gamma: float = 1.0
    w_min: float = 0.25
    lr: float = 5e-3
    ssop_r: int = 8
    sketch_y: int = 3
    sketch_z: int = 0                    # 0 -> derive from rho
    rho: float = 2.1
    xi: float = 1e-4                     # Eq. 16 threshold
    local_warmup_steps: int = 10         # steps before fingerprinting
    seed: int = 0
    num_classes: int = 4
    use_channel: bool = True
    use_ssop: bool = True
    model: str = "bert-base"             # split-model registry name
    layers: Optional[int] = None         # reduced-model depth (tests: 4;
                                         # None -> 8)
    bert_layers: Optional[int] = None    # DEPRECATED: use ``layers=``
    seq_len: int = 24                    # synthetic-task sequence length
    class_sharpness: float = 4.0         # synthetic-task separability
    background_frac: float = 0.5         # synthetic-task noise fraction
    cls_token: int = -1                  # >= 0: constant [CLS] at pos 0
    constrained_frac: float = 0.0        # fraction of slow/throttled devices
                                         # (paper §IV.A heterogeneity setup)
    dtype: str = "float32"               # params+activations; parity tests
                                         # use float64 (needs jax x64 mode)
    # -- convergence stack (docs/convergence.md) -------------------------
    aggregate: str = "product"           # LoRA aggregation space:
                                         # "product" (weight-delta mean,
                                         # anchored pinv re-fit) or
                                         # "factor" (legacy leafwise
                                         # mean, golden-pinned)
    clip_norm: float = 0.0               # >0: per-client global-norm clip
    head_lr: float = 0.0                 # >0: readout-head lr (adapters
                                         # keep ``lr``); 0 -> ``lr``
    server_opt: str = "none"             # cloud pseudo-gradient step:
                                         # "none" | "fedadam" | "fedams"
                                         # (overrides the method default)
    server_lr: float = 0.05              # server-opt lr (FedAdam tuning)
    pooling: str = "cls"                 # encoder readout: "cls" | "mean"
    vocab_size: int = 0                  # >0: override the model vocab
                                         # (small-vocab synthetic tasks)
    # -- update screening (docs/robustness.md); off by default and
    #    bit-inert when disabled (golden-pinned) ------------------------
    screen: bool = False                 # server-side update screening
    screen_norm_k: float = 4.0           # reject ||delta|| > k * median
    screen_cos_min: float = -0.5         # reject cos(delta, cohort mean)
                                         # below this (sign-flip catch)
    screen_trust_beta: float = 0.7       # trust-EMA retention
    screen_trust_floor: float = 0.15     # exclude trust EMA below this
    screen_min_cohort: int = 2           # fewer survivors -> trimmed mean
    screen_trim_frac: float = 0.25       # fallback per-side trim fraction

    def __post_init__(self):
        if self.aggregate not in ("product", "factor"):
            raise ValueError(f"unknown aggregate mode {self.aggregate!r}")
        if not 0.0 <= self.screen_trust_beta <= 1.0:
            raise ValueError("screen_trust_beta must be in [0, 1], "
                             f"got {self.screen_trust_beta}")
        if not 0.0 <= self.screen_trim_frac < 0.5:
            raise ValueError("screen_trim_frac must be in [0, 0.5), "
                             f"got {self.screen_trim_frac}")
        if self.server_opt not in ("none", "fedadam", "fedams"):
            raise ValueError(f"unknown server_opt {self.server_opt!r}")
        if self.pooling not in ("cls", "mean"):
            raise ValueError(f"unknown pooling {self.pooling!r}")
        # warn only when the deprecated spelling actually carries intent:
        # after resolution bert_layers mirrors layers, so reconstruction
        # round-trips (dataclasses.replace / FedConfig(**asdict(...)))
        # stay warning-free
        if self.bert_layers is not None and self.layers != self.bert_layers:
            warnings.warn(
                "FedConfig.bert_layers is deprecated; use FedConfig.layers "
                "(the federation is model-agnostic now)",
                DeprecationWarning, stacklevel=3)
            if self.layers is None:
                self.layers = self.bert_layers
        if self.layers is None:
            self.layers = 8
        self.bert_layers = self.layers   # keep legacy readers consistent


class Federation:
    """Simulation harness; ``run(method)`` with method in
    {'elsa', 'elsa-fixed', 'elsa-nocluster', 'fedavg', 'fedavg-random',
    'fedprox', 'fedams', 'vanilla'}.

    ``backend="batched"`` runs local training through the compiled
    vmap/scan engine; ``backend="reference"`` keeps the sequential eager
    path (parity baseline).  ``mesh=`` (built with
    :func:`repro.launch.mesh.make_federation_mesh`) shards the engine's
    stacked client axis across a device mesh; the default ``None`` keeps
    every round single-device.
    """

    def __init__(self, fed: FedConfig = FedConfig(),
                 backend: str = "batched", mesh=None):
        if backend not in ("batched", "reference"):
            raise ValueError(f"unknown backend {backend!r}")
        if mesh is not None and backend != "batched":
            raise ValueError("mesh sharding requires backend='batched'")
        self.backend = backend
        self.mesh = mesh
        self.fed = fed
        overrides = {}
        if fed.vocab_size:
            overrides["vocab_size"] = fed.vocab_size
        self.model = get_split_model(fed.model, num_layers=fed.layers,
                                     dtype=fed.dtype,
                                     pooling=(fed.pooling
                                              if fed.pooling != "cls"
                                              else None),
                                     **overrides)
        self.cfg = self.model.cfg
        self.task = SyntheticTaskConfig(vocab_size=self.cfg.vocab_size,
                                        num_classes=fed.num_classes,
                                        seq_len=fed.seq_len,
                                        class_sharpness=fed.class_sharpness,
                                        background_frac=fed.background_frac,
                                        cls_token=fed.cls_token,
                                        seed=fed.seed)
        self.topo = make_topology(fed.n_clients, fed.n_edges,
                                  constrained_frac=fed.constrained_frac,
                                  seed=fed.seed)
        self.data = make_federation_data(
            self.task, fed.n_clients, fed.total_examples, fed.alpha,
            poisoned_clients=fed.poisoned, seed=fed.seed,
            task_kind=self.model.task)
        self.test_tokens, self.test_labels = make_test_set(self.task, 512,
                                                           seed=fed.seed + 7)
        self.probe = make_probe_set(self.task, fed.probe_q, seed=fed.seed + 3)
        self.policy = split_mod.SplitPolicy(
            num_blocks=self.cfg.num_layers, o_fix=2, p_min=1,
            p_max=min(5, self.cfg.num_layers - 3))
        self.splits = split_mod.splits_for_population(
            self.topo.capacity, self.topo.bandwidth, self.policy)

        key = jax.random.PRNGKey(fed.seed)
        specs = self.model.specs(fed.num_classes)
        tree = init_tree(specs, key, jnp.dtype(fed.dtype))
        self.frozen, self.lora0 = tree["frozen"], tree["lora"]

        d = self.cfg.d_model
        z = fed.sketch_z or max(4, int(d / (fed.rho * fed.sketch_y)))
        self.plan = make_plan(d, fed.sketch_y, z, seed=fed.seed + 11)

        self._loss_grad_cache: Dict = {}
        # identity-keyed channels (identity == slot without a bound
        # population; with one, channel_for routes through the
        # population's identity LRU and this dict stays empty)
        self._channels: Dict[int, Channel] = {}
        self._ref_basis = None
        self._engine: Optional[BatchedEngine] = None
        self._probe_fn = None
        self._eval_fn = None

        # update screening (docs/robustness.md): the ledger always
        # exists (cheap, checkpointed), the screening stage only runs
        # when fed.screen is on — the off path stays golden bit-inert
        from repro.core.screening import ScreeningConfig, TrustLedger
        self.screening = ScreeningConfig(
            norm_k=fed.screen_norm_k, cos_min=fed.screen_cos_min,
            trust_floor=fed.screen_trust_floor,
            min_cohort=fed.screen_min_cohort,
            trim_frac=fed.screen_trim_frac)
        self.trust_ledger = TrustLedger(fed.n_clients,
                                        beta=fed.screen_trust_beta)
        self.screen_log: List = []
        # registry-backed population binding (docs/population.md);
        # installed by run(population=...) / the runtime schedulers
        self._population = None

    @property
    def engine(self) -> BatchedEngine:
        """Lazily-built compiled round executor (batched backend)."""
        if self._engine is None:
            self._engine = BatchedEngine(
                self.model, self.frozen, self.plan, lr=self.fed.lr,
                batch_size=self.fed.batch_size,
                use_channel=self.fed.use_channel,
                use_ssop=self.fed.use_ssop, mesh=self.mesh,
                head_lr=self.fed.head_lr or None,
                clip_norm=self.fed.clip_norm)
        return self._engine

    def server_optimizer(self, method: str):
        """Cloud pseudo-gradient optimizer, shared by the round loop and
        every runtime scheduler (so `policy="sync"` parity holds under
        any server-opt config).  ``FedConfig.server_opt`` overrides the
        method default; the legacy ``method="fedams"`` baseline keeps
        its historical untuned FedAMS(lr=1.0)."""
        fed = self.fed
        if fed.server_opt == "fedadam":
            return FedAdam(lr=fed.server_lr)
        if fed.server_opt == "fedams":
            return FedAMS(lr=fed.server_lr)
        return FedAMS(lr=1.0) if method == "fedams" else None

    def _default_split(self) -> Split:
        return Split(self.policy.p_max,
                     self.cfg.num_layers - self.policy.p_max - 2, 2)

    def split_for(self, client: int, use_split: bool = True) -> Split:
        """The tripartite split client ``client`` trains (and is billed
        for, in the event-driven runtime's cost model)."""
        return (Split(*self.splits[client]) if use_split
                else self._default_split())

    def client_weight(self, client: int) -> int:
        """FedAvg weight: the example count of the client currently
        occupying slot ``client`` (with a bound population the occupant
        is whatever registered id the round's cohort mapped there)."""
        if self._population is not None:
            return self._population.slot_weight(client)
        return len(self.data[client].tokens)

    def _bind_population(self, population):
        """Attach a registry-backed population for this run.  Accepts a
        :class:`~repro.population.PopulationConfig` (builds the runtime)
        or a prebuilt :class:`~repro.population.PopulationRuntime`;
        ``None`` detaches (the bit-inert legacy dict path)."""
        if population is None:
            self._population = None
            return None
        from repro.population import PopulationConfig, PopulationRuntime
        if isinstance(population, PopulationConfig):
            population = PopulationRuntime(self, population)
        elif not isinstance(population, PopulationRuntime):
            raise TypeError(
                f"population must be a PopulationConfig or "
                f"PopulationRuntime, got {type(population).__name__}")
        if population.federation is not self:
            raise ValueError("population is bound to a different federation")
        self._population = population
        return population

    # ------------------------------------------------------------------
    def channel_for(self, client: int, lora, emb=None) -> Channel:
        """Lazily build the client's SS-OP∘sketch channel.

        Channels are keyed by client *identity*: with a bound population
        ``client`` is a slot index and the call resolves through the
        population's identity-keyed channel LRU (the slot's occupant,
        :meth:`~repro.population.PopulationRuntime.channel_for_slot`);
        without one, identity == slot and the channel lives in the
        legacy ``_channels`` dict.

        ``emb`` lets callers share one probe forward across clients that
        create their channels from the same lora (the probe embeddings
        depend only on (lora, probe), not the client; only the seeded
        V_n rotation is per-client).
        """
        if not self.fed.use_channel:
            return Channel(None, None)
        if self._population is not None:
            return self._population.channel_for_slot(client)
        if client not in self._channels:
            if emb is None:
                emb = self._probe_embeddings(lora)
            ss = (make_ssop(emb, self.fed.ssop_r, "elsa-salt", client)
                  if self.fed.use_ssop else None)
            self._channels[client] = Channel(ss, self.plan)
        return self._channels[client]

    def _probe_embeddings(self, lora):
        return self.model.probe_repr(self.frozen, lora,
                                     jnp.asarray(self.probe))

    def _reference_basis(self):
        """Shared semantic basis for identity-keyed channels: top-r SVD
        of the *reference model's* probe embeddings, computed once.  In
        every golden-pinned path legacy channels are built from
        ``lora0`` embeddings too (elsa profiles from ``lora0``; the
        plain loops build lazily at round 0 where theta == ``lora0``),
        so the fixed basis is what makes an identity cohort bit-inert —
        and what makes an evicted identity's channel regenerate
        bit-exactly regardless of when it returns."""
        if self._ref_basis is None:
            self._ref_basis = semantic_subspace(
                self._probe_embeddings(self.lora0), self.fed.ssop_r)
        return self._ref_basis

    def _build_identity_channel(self, cid: int) -> Channel:
        """One registered identity's channel: shared reference basis +
        its own seeded rotation (Eq. 18 keyed on the id)."""
        ss = (make_ssop_from_basis(self._reference_basis(), "elsa-salt",
                                   cid)
              if self.fed.use_ssop else None)
        return Channel(ss, self.plan)

    # ------------------------------------------------------------------
    def _grad_fn(self, client: int, split: Split):
        # keyed on (client, split, use_ssop, use_channel) — NOT id(channel):
        # id() of a collected Channel can be reused by a new object, which
        # would silently pair a client with a stale cached loss
        key = (client, split.p, split.q, split.o,
               self.fed.use_ssop, self.fed.use_channel)
        if key not in self._loss_grad_cache:
            def loss(lora, batch, channel):
                return split_loss(self.model, self.frozen, lora, batch,
                                  split, channel)
            self._loss_grad_cache[key] = jax.value_and_grad(loss)
        return self._loss_grad_cache[key]

    def client_steps(self, client: int, lora, n_steps: int,
                     it, use_split=True, prox_anchor=None):
        """Run local training steps; returns (lora, mean loss).

        Sequential reference path: eager autodiff, one host sync per
        step.  The batched backend runs :meth:`group_steps` instead.
        """
        fed = self.fed
        split = (Split(*self.splits[client]) if use_split
                 else self._default_split())
        channel = self.channel_for(client, lora)
        gfn = self._grad_fn(client, split)
        lrs = adapter_head_lr_tree(lora, fed.lr, fed.head_lr or None)
        losses = []
        for _ in range(n_steps):
            tok, lab = next(it)
            batch = {"tokens": jnp.asarray(tok), "labels": jnp.asarray(lab)}
            lv, g = gfn(lora, batch, channel)
            if prox_anchor is not None:
                g = fedprox_gradient(g, lora, prox_anchor, 0.01)
            if fed.clip_norm > 0:
                g = clip_by_global_norm(g, fed.clip_norm)
            lora = jax.tree_util.tree_map(
                lambda p, gg, s: p - s * gg, lora, g, lrs)
            losses.append(float(lv))
        return lora, float(np.mean(losses))

    def group_steps(self, clients, theta, n_steps: int, iters,
                    use_split=True, prox_anchor=None, per_client=None):
        """Run one local round for a client group on the active backend.

        ``theta`` is either one shared LoRA tree or — for the fused
        cross-group dispatch of the sharded engine — a ``{client: tree}``
        dict of per-client starting points (clients of different edge
        groups carry their own edge model into one stacked round).
        Callers that know which form they pass should say so via
        ``per_client``; the default sniffs the dict's key types
        (:func:`~repro.federation.engine.is_client_map`), which is only
        safe while no registered model's LoRA pytree is integer-keyed.
        Returns ``{client: (lora, mean loss)}``.  The batched backend
        stacks the group per split bucket and runs the compiled
        vmap/scan round; the reference backend loops ``client_steps``.
        """
        if per_client is None:
            per_client = is_client_map(theta)
        if self.backend != "batched":
            return {n: self.client_steps(n, theta[n] if per_client
                                         else theta, n_steps, iters[n],
                                         use_split=use_split,
                                         prox_anchor=prox_anchor)
                    for n in clients}
        splits = {n: (Split(*self.splits[n]) if use_split
                      else self._default_split()) for n in clients}
        # all missing channels derive from the same theta -> one probe
        # forward shared across clients instead of N identical ones
        # (per-client thetas share it too when they are one object, the
        # fused first-dispatch case)
        emb = None
        shared = (theta if not per_client
                  else (theta[clients[0]]
                        if len({id(theta[n]) for n in clients}) == 1
                        else None))
        if self.fed.use_channel and self._population is None and \
                shared is not None and \
                any(n not in self._channels for n in clients):
            emb = self._probe_embeddings(shared)
        channels = {n: self.channel_for(n, theta[n] if per_client
                                        else theta, emb=emb)
                    for n in clients}
        batches = {n: [next(iters[n]) for _ in range(n_steps)]
                   for n in clients}
        return self.engine.run_clients(theta, clients, splits, channels,
                                       batches, prox_anchor=prox_anchor,
                                       per_client_theta=per_client)

    # ------------------------------------------------------------------
    def evaluate(self, lora) -> float:
        if self._eval_fn is None:
            # tokens stay an argument (not a closure) so XLA doesn't try
            # to constant-fold the embedding of the whole test set
            self._eval_fn = jax.jit(lambda lp, toks: self.model.forward(
                self.frozen, lp, toks)[1])
        logits = self._eval_fn(lora, jnp.asarray(self.test_tokens))
        return self.model.accuracy(logits, self.test_tokens,
                                   self.test_labels)

    # ------------------------------------------------------------------
    def _batched_probe_embeddings(self, loras):
        """Probe embeddings for a list of lora trees: (N, Q, D)."""
        if self._probe_fn is None:
            self._probe_fn = jax.jit(jax.vmap(
                lambda lp, toks: self.model.probe_repr(
                    self.frozen, lp, toks),
                in_axes=(0, None)))
        return self._probe_fn(stack_trees(loras), jnp.asarray(self.probe))

    def profile_clients(self):
        """Phase 1: warmup locally, fingerprint, trust, cluster.

        On the batched backend the warmup of all clients runs as one
        compiled vmap/scan round (they share the default split) and the
        probe forwards batch through a single vmapped jit call.
        """
        fed = self.fed
        iters = {n: infinite_batches(self.data[n].tokens,
                                     self.data[n].labels, fed.batch_size,
                                     seed=fed.seed + n)
                 for n in range(fed.n_clients)}
        clients = list(range(fed.n_clients))
        fps, norms, warm_loras = [], [], {}
        if self.backend == "batched":
            res = self.group_steps(clients, self.lora0,
                                   fed.local_warmup_steps, iters,
                                   use_split=False)
            warm_loras = {n: res[n][0] for n in clients}
            embs = self._batched_probe_embeddings(
                [warm_loras[n] for n in clients])
            for n in clients:
                fps.append(fingerprint(embs[n]))
                norms.append(np.asarray(jnp.linalg.norm(embs[n], axis=-1)))
        else:
            for n in clients:
                lora_n, _ = self.client_steps(n, self.lora0,
                                              fed.local_warmup_steps,
                                              iters[n], use_split=False)
                warm_loras[n] = lora_n
                emb = self._probe_embeddings(lora_n)
                fps.append(fingerprint(emb))
                norms.append(np.asarray(jnp.linalg.norm(emb, axis=-1)))
        div = divergence_matrix(fps)
        trust = trust_scores(div, np.stack(norms))
        result = clus.cluster_clients(div, trust, self.topo.latency,
                                      tau_max=fed.tau_max, gamma=fed.gamma,
                                      w_min=fed.w_min, seed=fed.seed)
        return div, trust, result, warm_loras

    # ------------------------------------------------------------------
    def _assign_groups(self, method: str, rng):
        """Phase-1 edge assignment shared by the round loop and the
        event-driven runtime: returns ``(groups, div, trust)``."""
        fed = self.fed
        use_cluster = method in ("elsa", "elsa-fixed")
        if method in ("elsa", "elsa-fixed", "elsa-nocluster"):
            div, trust, cres, _ = (self.profile_clients() if use_cluster
                                   else (None, None, None, None))
            if not use_cluster:   # random assignment ablation
                groups = {k: [] for k in range(fed.n_edges)}
                for n in range(fed.n_clients):
                    groups[rng.integers(0, fed.n_edges)].append(n)
                div = np.ones((fed.n_clients, fed.n_clients))
                np.fill_diagonal(div, 0)
                trust = np.ones(fed.n_clients)
            else:
                groups = {k: v for k, v in cres.groups.items()}
                if cres.escalated:
                    # Stage 4(ii): escalate to cloud-level aggregation
                    groups[-1] = list(cres.escalated)
                if not any(groups.values()):
                    # degenerate clustering: fall back to latency assignment
                    groups = {k: [] for k in range(fed.n_edges)}
                    for n in range(fed.n_clients):
                        groups[int(np.argmin(self.topo.latency[n]))].append(n)
        else:
            groups = {0: list(range(fed.n_clients))}
            div = np.zeros((fed.n_clients, fed.n_clients))
            trust = np.ones(fed.n_clients)
        # screening starts from the clustering-time
        # prediction-consistency trust as its EMA seed
        self.trust_ledger.seed(trust)
        return groups, div, trust

    def _edge_round(self, active, theta_k, steps: int, iters, *,
                    use_split: bool = True, prox_anchor=None):
        """One local round for ``active`` clients from edge model
        ``theta_k``; returns ``(locals_, weights, {client: loss})``."""
        res = self.group_steps(active, theta_k, steps, iters,
                               use_split=use_split,
                               prox_anchor=prox_anchor)
        locals_ = [res[n][0] for n in active]
        weights = [self.client_weight(n) for n in active]
        losses = {n: res[n][1] for n in active}
        return locals_, weights, losses

    def _fused_edge_round(self, actives, theta_ks, steps: int, iters, *,
                          use_split: bool = True, prox_anchor=None):
        """One local round for *every* edge group in a single dispatch:
        each client carries its group's edge model into one stacked
        (and, with a mesh, sharded) engine round instead of one
        ``run_clients`` call per group.  Returns
        ``(new_theta_ks, {client: loss})`` with each group's FedAvg
        applied over its own members."""
        thetas = {n: theta_ks[k] for k, act in actives.items() for n in act}
        all_active = [n for act in actives.values() for n in act]
        res = self.group_steps(all_active, thetas, steps, iters,
                               use_split=use_split, prox_anchor=prox_anchor,
                               per_client=True)
        if self._population is not None:
            for k, act in actives.items():
                self._population.note_updates(
                    act, [res[n][0] for n in act], theta_ks[k])
        new_ks = {k: self.screened_aggregate(
                      act, [res[n][0] for n in act],
                      [self.client_weight(n) for n in act], theta_ks[k])
                  for k, act in actives.items()}
        return new_ks, {n: res[n][1] for n in all_active}

    # -- update screening (docs/robustness.md) -------------------------
    def _screen_identities(self, clients):
        """(ledger, keys) for one screening pass.  With a bound
        population, verdicts are recorded against client *identities* —
        each slot resolves to its pinned dispatch-time id, so a
        straggler arriving after a cohort swap credits/blames the
        identity that actually trained, never the slot's new occupant —
        through the identity-keyed ledger facade.  Without one,
        identity == slot and the slot ledger is used directly."""
        if self._population is None:
            return self.trust_ledger, list(clients)
        pop = self._population
        return pop.ledger_view, [pop.pinned(int(n)) for n in clients]

    def screened_aggregate(self, clients, trees, weights, base):
        """Edge aggregation with the optional screening stage.

        With ``FedConfig.screen`` off this IS
        ``agg.aggregate_adapters(trees, weights)`` — same call, same
        floats, golden bit-inert.  With it on, updates are screened
        against ``base`` (the model they were dispatched from), the
        trust EMA is updated from the verdicts, survivors are
        trust-down-weighted, and an over-screened cohort falls back to
        the trimmed mean (:mod:`repro.core.screening`).
        """
        if not self.fed.screen:
            return agg.aggregate_adapters(trees, weights,
                                          mode=self.fed.aggregate)
        from repro.core.screening import screen_and_aggregate
        from repro.federation.engine import screen_stats
        ledger, keys = self._screen_identities(clients)
        out, report = screen_and_aggregate(
            base, trees, weights, keys, ledger,
            self.screening, mode=self.fed.aggregate, stats_fn=screen_stats)
        self.screen_log.append(report)
        return out

    def screen_cohort(self, clients, trees, weights, base):
        """Screening without aggregation, for schedulers that combine
        arrivals with an anchor term (the deadline policy): returns the
        surviving ``(trees, weights)`` with trust-scaled weights.  A
        fully-screened-out cohort returns empty lists — the caller's
        anchor then carries the round."""
        if not self.fed.screen:
            return list(trees), list(weights)
        from repro.core.screening import screen_updates
        from repro.federation.engine import screen_stats
        ledger, keys = self._screen_identities(clients)
        report = screen_updates(base, trees, weights, keys,
                                ledger, self.screening,
                                stats_fn=screen_stats)
        self.screen_log.append(report)
        kept_trees = [trees[i] for i in report.kept]
        kept_wts = [float(weights[i]) * ledger.weight(keys[i])
                    for i in report.kept]
        return kept_trees, kept_wts

    def fusion_trust(self, trust, members) -> float:
        """Mean trust feeding an edge's cloud-fusion weight (Eq. 14):
        the live screening EMA when screening is on, the static
        clustering-time scores otherwise (bit-inert default)."""
        if self.fed.screen:
            return float(np.mean(self.trust_ledger.scores[list(members)]))
        return float(np.mean(trust[list(members)]))

    # ------------------------------------------------------------------
    def run(self, method: str = "elsa", global_rounds: int = 10,
            steps_per_round: int = 4, eval_every: int = 1,
            log: bool = False, runtime=None, checkpoint=None,
            resume_from: Optional[str] = None, population=None) -> Dict:
        """Run the federation.

        ``runtime=None`` keeps the historical round-synchronous loop
        (no wall-clock model).  Passing a
        :class:`repro.runtime.RuntimeConfig` delegates to the
        event-driven :class:`repro.runtime.EdgeRuntime` — histories gain
        a simulated ``time`` axis and an event ``trace``; with
        ``policy="sync"`` and no churn the training math (and therefore
        the history) is identical to the historical loop.

        ``checkpoint`` (a :class:`repro.checkpoint.CheckpointConfig`)
        snapshots the full federation state on a rolling cadence;
        ``resume_from`` (a checkpoint file or its directory) restores
        one and continues — bit-identically to the uninterrupted run on
        this loop and the sync runtime policy (docs/robustness.md).

        ``population`` (a :class:`repro.population.PopulationConfig`)
        decouples the registered client population from the
        ``n_clients`` slots: each round samples a cohort of registered
        ids into the slots (docs/population.md).  With
        ``registered == n_clients`` the run is bit-identical to
        ``population=None``.
        """
        if runtime is not None:
            from repro.runtime import EdgeRuntime
            return EdgeRuntime(self, runtime).run(
                method, global_rounds=global_rounds,
                steps_per_round=steps_per_round, eval_every=eval_every,
                log=log, checkpoint=checkpoint, resume_from=resume_from,
                population=population)
        from repro.checkpoint import federation as fedckpt
        from repro.data.pipeline import CountingIterator
        fed = self.fed
        rng = np.random.default_rng(fed.seed + 5)
        history = {"round": [], "accuracy": [], "loss": [], "delta": []}

        use_split_dyn = method not in ("elsa-fixed",)
        pop = self._bind_population(population)
        iters = pop.iters if pop is not None else \
            {n: CountingIterator(
                 infinite_batches(self.data[n].tokens,
                                  self.data[n].labels, fed.batch_size,
                                  seed=fed.seed + 100 + n))
             for n in range(fed.n_clients)}
        server_opt = self.server_optimizer(method)

        start_round, last_delta = 0, float("inf")
        if resume_from is not None:
            state = fedckpt.load_state(fedckpt.resolve(resume_from))
            res = fedckpt.restore_run(self, state, method=method,
                                      steps_per_round=steps_per_round,
                                      iters=iters, rng=rng, population=pop)
            groups, div, trust = res.groups, res.div, res.trust
            theta, server_state = res.theta, res.server_state
            history, client_losses = res.history, res.client_losses
            start_round, last_delta = res.round_idx + 1, res.delta
        else:
            with tm.span("profile", method=method):
                groups, div, trust = self._assign_groups(method, rng)
            if pop is not None:
                pop.after_assign(groups)
            theta = self.lora0
            server_state = server_opt.init(theta) if server_opt else None
            client_losses: Dict[int, List[float]] = {
                n: [] for n in range(fed.n_clients)}
        ckpt = fedckpt.Checkpointer(checkpoint) if checkpoint else None
        if last_delta <= fed.xi:
            # the checkpointed run had already converged (Eq. 16)
            history["final_accuracy"] = history["accuracy"][-1]
            history["client_losses"] = client_losses
            self.last_theta = theta
            return history
        # with a mesh, all edge groups dispatch as one sharded round per
        # edge-round index (devices see one big stacked cohort, not one
        # small dispatch per group); single-device keeps the historical
        # per-group dispatch so default runs stay bit-identical
        fuse = self.backend == "batched" and self.mesh is not None
        for g in range(start_round, global_rounds):
            if pop is not None:
                pop.begin_round(g)
            edge_thetas, edge_alphas, losses = {}, {}, []
            actives = {}
            for k, members in groups.items():
                if not members:
                    continue
                active = members
                if method == "fedavg-random":
                    m = max(1, len(members) // 2)
                    active = list(rng.choice(members, m, replace=False))
                actives[k] = active
            anchor = theta if method == "fedprox" else None
            if fuse:
                theta_ks = {k: theta for k in actives}
                round_maps = []
                for _ in range(fed.t_rounds):
                    with tm.span("local_steps", round=g,
                                 n_clients=sum(len(a) for a
                                               in actives.values())):
                        theta_ks, loss_map = self._fused_edge_round(
                            actives, theta_ks, steps_per_round, iters,
                            use_split=use_split_dyn, prox_anchor=anchor)
                    round_maps.append(loss_map)
                # record group-major (all of group k's edge rounds, then
                # the next group), matching the per-group path exactly —
                # np.mean over `losses` is order-sensitive in the last
                # ulp, and the 1-device mesh history is pinned bitwise
                for k, act in actives.items():
                    for loss_map in round_maps:
                        for n in act:
                            losses.append(loss_map[n])
                            client_losses[n].append(loss_map[n])
                edge_thetas = theta_ks
            else:
                for k, active in actives.items():
                    theta_k = theta
                    for _ in range(fed.t_rounds):
                        with tm.span("local_steps", round=g, edge=k,
                                     n_clients=len(active)):
                            locals_, weights, loss_map = self._edge_round(
                                active, theta_k, steps_per_round, iters,
                                use_split=use_split_dyn,
                                prox_anchor=anchor)
                        for n in active:
                            losses.append(loss_map[n])
                            client_losses[n].append(loss_map[n])
                        if pop is not None:
                            pop.note_updates(active, locals_, theta_k)
                        with tm.span("edge_agg", round=g, edge=k,
                                     n_updates=len(active)):
                            theta_k = self.screened_aggregate(
                                active, locals_, weights, theta_k)
                    edge_thetas[k] = theta_k
            for k, active in actives.items():
                edge_alphas[k] = agg.edge_weight(
                    agg.mean_pairwise_kld(div, active),
                    self.fusion_trust(trust, active))

            with tm.span("cloud_agg", round=g, n_edges=len(edge_thetas)):
                if method in ("elsa", "elsa-fixed", "elsa-nocluster"):
                    theta_new = agg.cloud_aggregate(edge_thetas,
                                                    edge_alphas,
                                                    mode=fed.aggregate)
                else:
                    ws = {k: 1.0 for k in edge_thetas}
                    theta_new = agg.cloud_aggregate(edge_thetas, ws,
                                                    mode=fed.aggregate)

                if server_opt is not None:
                    pseudo = jax.tree_util.tree_map(lambda a, b: a - b,
                                                    theta, theta_new)
                    theta_new, server_state = server_opt.update(
                        theta, pseudo, server_state)
                delta = agg.global_delta(theta_new, theta)
            theta = theta_new
            if g % eval_every == 0 or g == global_rounds - 1:
                with tm.span("eval", round=g):
                    acc = self.evaluate(theta)
                history["round"].append(g)
                history["accuracy"].append(acc)
                history["loss"].append(float(np.mean(losses)))
                history["delta"].append(delta)
                if log:
                    print(f"[{method}] round {g}: acc={acc:.4f} "
                          f"loss={np.mean(losses):.4f} delta={delta:.2e}")
            if pop is not None:
                # write the round's outcomes back before any snapshot so
                # a resume sees the post-round registry
                pop.end_round(g)
            if ckpt is not None and ckpt.due(g, global_rounds - 1, delta,
                                            fed.xi):
                ckpt.save(g, fedckpt.build_state(
                    self, method=method, steps_per_round=steps_per_round,
                    round_idx=g, theta=theta, server_state=server_state,
                    rng=rng, iters=iters, history=history,
                    client_losses=client_losses, groups=groups, div=div,
                    trust=trust, delta=delta, population=pop))
            tm.end_round(g)
            if delta <= fed.xi:
                break
        history["final_accuracy"] = history["accuracy"][-1]
        history["client_losses"] = client_losses
        self.last_theta = theta           # final aggregated LoRA (parity)
        return history
