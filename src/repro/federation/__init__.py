from repro.federation.simulation import Federation, FedConfig  # noqa: F401
