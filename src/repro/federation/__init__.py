from repro.federation.engine import (BatchedEngine, broadcast_tree,
                                     index_tree, stack_trees)  # noqa: F401
from repro.federation.simulation import Federation, FedConfig  # noqa: F401
