"""Edge-network geometry and resource profiles (ELSA §IV.A: 20 clients,
4 edge servers in an 8km x 8km area; B_n in [50, 100] Mbps), plus the
client availability (churn) traces consumed by the event-driven runtime
(:mod:`repro.runtime`): per-client alternating on/off renewal processes
with exponential dwell times, and the :class:`FaultTrace` companion that
injects crashes, dropped/duplicated uplinks, and corrupted adapter
updates on a deterministic seeded schedule (docs/robustness.md)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Topology:
    client_xy: np.ndarray      # (N, 2) km
    edge_xy: np.ndarray        # (K, 2) km
    latency: np.ndarray        # (N, K) ms round-trip
    bandwidth: np.ndarray      # (N,) bytes/s uplink
    capacity: np.ndarray       # (N,) FLOP/s


def make_topology(n_clients: int, n_edges: int, *, area_km: float = 8.0,
                  base_ms: float = 20.0, ms_per_km: float = 25.0,
                  jitter_ms: float = 30.0,
                  bw_mbps: Tuple[float, float] = (50.0, 100.0),
                  flops_range: Tuple[float, float] = (5e9, 1e11),
                  constrained_frac: float = 0.0,
                  seed: int = 0) -> Topology:
    rng = np.random.default_rng(seed)
    cxy = rng.uniform(0, area_km, (n_clients, 2))
    # edges on a grid
    g = int(np.ceil(np.sqrt(n_edges)))
    pts = [(area_km * (i + 0.5) / g, area_km * (j + 0.5) / g)
           for i in range(g) for j in range(g)]
    exy = np.asarray(pts[:n_edges])
    dist = np.linalg.norm(cxy[:, None, :] - exy[None, :, :], axis=-1)
    lat = base_ms + ms_per_km * dist + rng.exponential(jitter_ms,
                                                       size=dist.shape)
    bw = rng.uniform(bw_mbps[0], bw_mbps[1], n_clients) * 1e6 / 8.0
    cap = rng.uniform(*flops_range, n_clients)
    if constrained_frac > 0:
        k = int(constrained_frac * n_clients)
        idx = rng.choice(n_clients, k, replace=False)
        cap[idx] = rng.uniform(flops_range[0], flops_range[0] * 4, k)
        bw[idx] = bw[idx] * 0.3
    return Topology(cxy, exy, lat, bw, cap)


# ---------------------------------------------------------------------------
# client availability / churn
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ChurnTrace:
    """Per-client offline intervals over a finite horizon.

    ``offline[n]`` is an (M_n, 2) array of non-overlapping, sorted
    ``[start, end)`` intervals during which client n is unreachable.
    Work that overlaps an offline interval pauses and resumes on rejoin
    (device churn, not data loss).  Beyond ``horizon_s`` every client is
    treated as always-on, so simulations that outrun the trace stay
    well-defined.
    """
    offline: List[np.ndarray]
    horizon_s: float

    def is_online(self, n: int, t: float) -> bool:
        for s, e in self.offline[n]:
            if s <= t < e:
                return False
            if s > t:
                break
        return True

    def next_online(self, n: int, t: float) -> float:
        """Earliest time >= t at which client n is online."""
        for s, e in self.offline[n]:
            if s <= t < e:
                return float(e)
            if s > t:
                break
        return t

    def finish_time(self, n: int, start: float, work_s: float) -> float:
        """When ``work_s`` seconds of on-device work started at ``start``
        completes, pausing across every offline interval it straddles."""
        t = self.next_online(n, start)
        remaining = work_s
        for s, e in self.offline[n]:
            if e <= t:
                continue
            gap = s - t               # online time before this outage
            if gap >= remaining:
                return t + remaining
            remaining -= max(gap, 0.0)
            t = float(e)              # pause: resume at rejoin
        return t + remaining


def always_on(n_clients: int) -> ChurnTrace:
    """Degenerate trace: every client permanently available."""
    return ChurnTrace([np.zeros((0, 2))] * n_clients, 0.0)


def make_churn_trace(n_clients: int, horizon_s: float, *,
                     mean_on_s: float = 60.0, mean_off_s: float = 20.0,
                     churn_frac: float = 1.0, seed: int = 0,
                     version: int = 2) -> ChurnTrace:
    """Alternating-renewal availability traces (exponential dwell times).

    A ``churn_frac`` fraction of clients cycles online/offline with mean
    dwell times ``mean_on_s`` / ``mean_off_s``; the rest are always on.
    Every client starts online (the first outage begins after one on-dwell),
    matching the common FL assumption that the round-0 cohort is reachable.

    ``version=2`` (default) generates all clients' renewal processes with
    batched draws — 10^5 population-scale clients in milliseconds where
    the per-client loop took minutes.  ``version=1`` keeps the original
    sequential generator; the two sample the *same distribution* but not
    the same bits (the legacy generator interleaves every client's draws
    on one shared stream, which no batched layout can reproduce), so v1
    stays available for traces pinned by old seeds and is golden-anchored
    in ``tests/test_population.py``.
    """
    if version not in (1, 2):
        raise ValueError(f"unknown churn-trace version {version}")
    rng = np.random.default_rng(seed)
    churny = rng.choice(n_clients, int(round(churn_frac * n_clients)),
                        replace=False)
    if version == 1:
        churny_set = set(churny.tolist())
        offline: List[np.ndarray] = []
        for n in range(n_clients):
            if n not in churny_set:
                offline.append(np.zeros((0, 2)))
                continue
            ivals, t = [], float(rng.exponential(mean_on_s))
            while t < horizon_s:
                off = float(rng.exponential(mean_off_s))
                ivals.append((t, t + off))
                t += off + float(rng.exponential(mean_on_s))
            offline.append(np.asarray(ivals, float).reshape(-1, 2))
        return ChurnTrace(offline, float(horizon_s))

    offline = [np.zeros((0, 2))] * n_clients
    m = len(churny)
    if m:
        # batched renewal construction: draw on/off dwell blocks for all
        # churny clients at once and cumsum the interleaved sequence;
        # extend by more columns for the (exponentially rare) clients
        # whose renewal process hasn't crossed the horizon yet
        guess = max(4, int(horizon_s / (mean_on_s + mean_off_s) * 2) + 8)
        ons = rng.exponential(mean_on_s, (m, guess))
        offs = rng.exponential(mean_off_s, (m, guess))
        while (ons.sum(1) + offs.sum(1) < horizon_s).any():
            ons = np.concatenate(
                [ons, rng.exponential(mean_on_s, (m, guess))], axis=1)
            offs = np.concatenate(
                [offs, rng.exponential(mean_off_s, (m, guess))], axis=1)
        # outage i starts after i+1 on-dwells and i off-dwells
        starts = np.cumsum(ons, axis=1)
        starts[:, 1:] += np.cumsum(offs[:, :-1], axis=1)
        ends = starts + offs
        live = starts < horizon_s
        counts = live.sum(1)
        flat = np.stack([starts[live], ends[live]], axis=-1)
        for cid, ivals in zip(churny,
                              np.split(flat, np.cumsum(counts)[:-1])):
            offline[int(cid)] = ivals
    return ChurnTrace(offline, float(horizon_s))


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------

FAULT_KINDS = ("crash", "drop", "dup", "corrupt")
CORRUPT_MODES = ("nan", "inf", "signflip", "scale")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected fault on a single client dispatch.

    ``kind``: ``"crash"`` (the client dies mid-round — its work is lost,
    not paused; churn models the *paused* case), ``"drop"`` (the client
    finishes but its uplink never reaches the edge), ``"dup"`` (the
    uplink arrives twice), or ``"corrupt"`` (the update arrives
    mangled, flavored by ``mode``: all-NaN, all-Inf, sign-flipped about
    the dispatch model, or norm-scaled Byzantine
    ``base + scale * (update - base)``).
    ``at_frac``: for crashes, the fraction of the round's duration
    survived before dying.
    """
    kind: str
    mode: str = ""
    scale: float = 10.0
    at_frac: float = 0.5


@dataclasses.dataclass
class FaultTrace:
    """Seeded per-dispatch fault schedule, the :class:`ChurnTrace`
    companion for *misbehavior* rather than availability.

    The fault hitting client ``n``'s ``i``-th dispatch is a pure
    function of ``(seed, n, i)`` — sampled from a
    ``np.random.SeedSequence(seed, spawn_key=(n, i))`` stream, not from
    shared RNG state — so the schedule is identical across schedulers
    and across screened/unscreened runs (the screening comparison in
    ``bench_fault_tolerance`` sees the same faults on both arms).
    Only clients in ``faulty`` misbehave (``None`` = everyone is
    eligible); per dispatch, at most one fault fires, with kind
    probabilities ``crash/drop/dup/corrupt_rate``.
    """
    n_clients: int
    crash_rate: float = 0.0
    drop_rate: float = 0.0
    dup_rate: float = 0.0
    corrupt_rate: float = 0.0
    corrupt_modes: Tuple[str, ...] = ("nan", "signflip", "scale")
    corrupt_scale: float = 10.0
    faulty: Optional[Tuple[int, ...]] = None
    seed: int = 0

    def __post_init__(self):
        rates = (self.crash_rate, self.drop_rate, self.dup_rate,
                 self.corrupt_rate)
        if any(r < 0 for r in rates) or sum(rates) > 1.0 + 1e-9:
            raise ValueError(f"fault rates must be >= 0 and sum <= 1, "
                             f"got {rates}")
        bad = [m for m in self.corrupt_modes if m not in CORRUPT_MODES]
        if bad:
            raise ValueError(f"unknown corrupt modes {bad}; "
                             f"expected among {CORRUPT_MODES}")
        self._faulty_set = (None if self.faulty is None
                            else frozenset(self.faulty))

    def sample(self, client: int, dispatch_idx: int) -> Optional[Fault]:
        """The fault (or None) hitting this client's i-th dispatch."""
        if self._faulty_set is not None and client not in self._faulty_set:
            return None
        rng = np.random.default_rng(np.random.SeedSequence(
            self.seed, spawn_key=(client, dispatch_idx)))
        u = float(rng.random())
        for kind, rate in (("crash", self.crash_rate),
                           ("drop", self.drop_rate),
                           ("dup", self.dup_rate),
                           ("corrupt", self.corrupt_rate)):
            if u < rate:
                mode, scale = "", self.corrupt_scale
                if kind == "corrupt":
                    mode = self.corrupt_modes[
                        int(rng.integers(len(self.corrupt_modes)))]
                return Fault(kind, mode=mode, scale=scale,
                             at_frac=float(rng.random()))
            u -= rate
        return None


def make_fault_trace(n_clients: int, *, faulty_frac: float = 1.0,
                     crash_rate: float = 0.0, drop_rate: float = 0.0,
                     dup_rate: float = 0.0, corrupt_rate: float = 0.0,
                     corrupt_modes: Tuple[str, ...] = ("nan", "signflip",
                                                       "scale"),
                     corrupt_scale: float = 10.0,
                     seed: int = 0) -> FaultTrace:
    """Pick a seeded ``faulty_frac`` subset of clients and give them the
    requested per-dispatch fault rates (everyone else stays honest)."""
    rng = np.random.default_rng(seed)
    k = int(round(faulty_frac * n_clients))
    faulty = tuple(sorted(int(x) for x in
                          rng.choice(n_clients, k, replace=False)))
    return FaultTrace(n_clients, crash_rate=crash_rate, drop_rate=drop_rate,
                      dup_rate=dup_rate, corrupt_rate=corrupt_rate,
                      corrupt_modes=tuple(corrupt_modes),
                      corrupt_scale=corrupt_scale, faulty=faulty, seed=seed)


def corrupt_update(base, update, fault: Fault):
    """Apply a ``corrupt`` fault to an arriving adapter update.

    ``base`` is the model the client was dispatched from: sign-flip and
    Byzantine scaling act on the *delta* the client trained, which is
    what a malicious participant controls.
    """
    import jax
    import jax.numpy as jnp
    t = jax.tree_util.tree_map
    if fault.mode == "nan":
        return t(lambda u: jnp.full_like(u, jnp.nan), update)
    if fault.mode == "inf":
        return t(lambda u: jnp.full_like(u, jnp.inf), update)
    if fault.mode == "signflip":
        return t(lambda b, u: (2.0 * b - u).astype(u.dtype), base, update)
    if fault.mode == "scale":
        return t(lambda b, u: (b + fault.scale * (u - b)).astype(u.dtype),
                 base, update)
    raise ValueError(f"not a corrupt fault: {fault!r}")
