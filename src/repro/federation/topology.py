"""Edge-network geometry and resource profiles (ELSA §IV.A: 20 clients,
4 edge servers in an 8km x 8km area; B_n in [50, 100] Mbps)."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class Topology:
    client_xy: np.ndarray      # (N, 2) km
    edge_xy: np.ndarray        # (K, 2) km
    latency: np.ndarray        # (N, K) ms round-trip
    bandwidth: np.ndarray      # (N,) bytes/s uplink
    capacity: np.ndarray       # (N,) FLOP/s


def make_topology(n_clients: int, n_edges: int, *, area_km: float = 8.0,
                  base_ms: float = 20.0, ms_per_km: float = 25.0,
                  jitter_ms: float = 30.0,
                  bw_mbps: Tuple[float, float] = (50.0, 100.0),
                  flops_range: Tuple[float, float] = (5e9, 1e11),
                  constrained_frac: float = 0.0,
                  seed: int = 0) -> Topology:
    rng = np.random.default_rng(seed)
    cxy = rng.uniform(0, area_km, (n_clients, 2))
    # edges on a grid
    g = int(np.ceil(np.sqrt(n_edges)))
    pts = [(area_km * (i + 0.5) / g, area_km * (j + 0.5) / g)
           for i in range(g) for j in range(g)]
    exy = np.asarray(pts[:n_edges])
    dist = np.linalg.norm(cxy[:, None, :] - exy[None, :, :], axis=-1)
    lat = base_ms + ms_per_km * dist + rng.exponential(jitter_ms,
                                                       size=dist.shape)
    bw = rng.uniform(bw_mbps[0], bw_mbps[1], n_clients) * 1e6 / 8.0
    cap = rng.uniform(*flops_range, n_clients)
    if constrained_frac > 0:
        k = int(constrained_frac * n_clients)
        idx = rng.choice(n_clients, k, replace=False)
        cap[idx] = rng.uniform(flops_range[0], flops_range[0] * 4, k)
        bw[idx] = bw[idx] * 0.3
    return Topology(cxy, exy, lat, bw, cap)
