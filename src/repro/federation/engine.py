"""Batched federation engine: vmap-over-clients split training.

The sequential reference in :mod:`repro.federation.simulation` simulates
one client at a time with un-jitted autodiff — wall-clock scales as
clients × rounds × steps with a host sync per client-step.  This engine
compiles one whole local round per split configuration:

- per-client LoRA pytrees are stacked along a leading client axis and the
  split-training gradient step (including the SS-OP∘sketch channel) is
  ``jax.vmap``-ed across every active client in the group;
- per-client SS-OP bases stack the same way (``SSOP`` is a pytree, so a
  stacked ``SSOP(u, v, w, w_inv)`` vmaps straight into the channel) while
  the ``SketchPlan`` — shared by all clients — is closed over once with
  its precomputed signed-selection tensor;
- the ``steps_per_round`` local-step loop is a ``jax.lax.scan`` over
  pre-gathered batch stacks from :mod:`repro.data.pipeline` (ragged
  epoch-tail batches are padded with zero-weight rows so every client
  shares one compiled shape);
- the round function is jit-compiled with the LoRA stack donated (on
  accelerators), so per-client losses come back as a single
  ``(steps, N)`` device array — one host sync per round instead of one
  per client-step.

Clients are bucketed by their ``Split`` configuration; each bucket
compiles once and is reused every round.  Cohorts are additionally
padded up to a small ladder of fixed sizes (:data:`BUCKET_LADDER`) with
zero-weight phantom clients, so schedulers that dispatch varying-size
ready sets (the deadline policy's straggler carry-over, churny async
rounds) reuse one compiled executable per (split, bucket size) instead
of recompiling for every distinct cohort size.  The FedProx anchor term
vectorizes by broadcasting the shared anchor tree against the
client-stacked parameters (:func:`repro.optim.fedprox_gradient`).

The client update supports the convergence stack (docs/convergence.md):
per-client global-norm gradient clipping (``clip_norm`` — vmapped along
the stacked client axis, so each client's cap is its own) and per-group
learning rates (``head_lr`` for every leaf outside the ``blocks`` /
``prefix`` adapter subtrees).  Both default off, in which case the
update is bit-identical to the historical ``p - lr * g``.

Passing ``mesh=`` (see :func:`repro.launch.mesh.make_federation_mesh`)
shards the stacked client axis across the mesh's ``("clients",)`` (or
``("pod", "clients")``) axes via :class:`jax.sharding.NamedSharding`:
the LoRA stacks, SS-OP stacks, and ``(steps, N, ...)`` batch stacks are
placed with their client dimension split across devices while the
frozen split-model parameters (and the FedProx anchor) stay replicated.
Because per-client computation is independent along the vmapped axis,
the round partitions without any cross-device collectives; cohorts pad
to bucket sizes divisible by the mesh's client-axis extent so the shard
split is even.  Sharding only changes array placement — the compiled
math, the compile count (one per (split, ladder size)), and the
single-device history are unchanged.

The engine is model-agnostic: it dispatches on the
:class:`~repro.models.split_api.SplitModel` protocol, so any registered
architecture (BERT encoder, dense causal LMs, ...) runs through the same
compiled path.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro import telemetry as tm
from repro.core.sketch import SketchPlan
from repro.core.split_training import Channel, Split, weighted_split_loss
from repro.core.ssop import SSOP
from repro.data.pipeline import stack_padded_batches
from repro.launch.mesh import client_axes
from repro.models.split_api import as_split_model
from repro.optim import (adapter_head_lr_tree, clip_by_global_norm,
                         fedprox_gradient)

PROX_MU = 0.01   # matches the reference path's hardcoded FedProx weight

#: Cohort sizes the engine compiles for.  Every size <= 8 is exact (small
#: federations and parity tests see zero padding); above that the ladder
#: grows geometrically (<= 25% padding waste), bounding the number of
#: compiled executables per split at O(log N) instead of O(N distinct
#: cohort sizes).
BUCKET_LADDER = (1, 2, 3, 4, 5, 6, 7, 8, 10, 12, 14, 16,
                 20, 24, 28, 32, 40, 48, 56, 64)


def bucket_size(n: int, multiple: int = 1) -> int:
    """Smallest ladder size >= n that is a multiple of ``multiple``
    (the mesh's client-axis extent, so shards split evenly).

    Beyond the top ladder entry the cohort rounds up to the next
    shard-multiple of ``n`` itself.  The old lcm(16, multiple) stepping
    over-padded large cohorts badly — e.g. 65 clients on a 3-shard mesh
    padded to 96 (48% phantom work) where 66 suffices — and population
    cohorts routinely exceed max(BUCKET_LADDER).
    """
    for s in BUCKET_LADDER:
        if s >= n and s % multiple == 0:
            return s
    return -(-n // multiple) * multiple


def placement_platform(mesh: Optional[Mesh] = None) -> str:
    """Platform the engine's arrays actually live on: the mesh's devices
    when sharding, the process default backend otherwise."""
    if mesh is not None:
        return mesh.devices.flat[0].platform
    return jax.default_backend()


def donate_buffers(platform: str) -> bool:
    """Whether to donate the LoRA stacks on this placement — CPU XLA has
    no donation support, so donating there only emits per-call
    warnings."""
    return platform != "cpu"


# ---------------------------------------------------------------------------
# stacked-pytree helpers
# ---------------------------------------------------------------------------

def is_client_map(theta) -> bool:
    """True when ``theta`` is a {client-id: tree} map (integer keys —
    Python or numpy ints, e.g. cohorts sampled via ``rng.choice``)
    rather than a single LoRA pytree (whose dict nodes have string
    keys)."""
    return isinstance(theta, dict) and bool(theta) and \
        all(isinstance(k, (int, np.integer)) and not isinstance(k, bool)
            for k in theta)


def stack_trees(trees: Sequence):
    """[tree, ...] -> one tree with a leading client axis on every leaf."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def broadcast_tree(tree, n: int):
    """Replicate a tree n times along a new leading client axis."""
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (n,) + p.shape), tree)


def index_tree(tree, i: int):
    """Slice client i out of a stacked tree (stays on device, lazy)."""
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def unstack_tree(tree, n: int) -> List:
    return [index_tree(tree, i) for i in range(n)]


def stack_ssops(ssops: Sequence[SSOP]) -> SSOP:
    """Stack per-client SS-OPs into one vmappable SSOP of (N, ...) leaves."""
    def field(name):
        vals = [getattr(s, name) for s in ssops]
        return None if vals[0] is None else jnp.stack(vals)
    return SSOP(u=field("u"), v=field("v"), w=field("w"),
                w_inv=field("w_inv"))


@jax.jit
def _screen_stats(stack, base, weights):
    """Per-client delta statistics for the screening stage: for each
    stacked client update vs the shared dispatch model ``base``, whether
    every leaf is finite, the global delta norm, and the cosine against
    the finite-masked weighted-mean delta of the cohort."""
    deltas = jax.tree_util.tree_map(
        lambda s, b: s.astype(jnp.float32) - b.astype(jnp.float32)[None],
        stack, base)
    leaves = jax.tree_util.tree_leaves(deltas)
    axes = lambda l: tuple(range(1, l.ndim))
    fin = jnp.ones(leaves[0].shape[0], bool)
    for l in leaves:
        fin = fin & jnp.all(jnp.isfinite(l), axis=axes(l))
    sq = sum(jnp.sum(l * l, axis=axes(l)) for l in leaves)
    norms = jnp.sqrt(sq)
    # cohort mean delta over finite updates only (NaN leaves zeroed so
    # one poisoned client can't poison the reference direction)
    wmask = jnp.asarray(weights, jnp.float32) * fin
    wsum = jnp.maximum(wmask.sum(), 1e-12)
    mean = [jnp.einsum("n,n...->...",
                       wmask, jnp.where(jnp.isfinite(l), l, 0.0)) / wsum
            for l in leaves]
    dot = sum(jnp.sum(l * m[None], axis=axes(l))
              for l, m in zip(leaves, mean))
    mnorm = jnp.sqrt(sum(jnp.sum(m * m) for m in mean))
    cos = dot / jnp.maximum(norms * mnorm, 1e-12)
    return fin, norms, cos


def screen_stats(base, trees: Sequence, weights: Sequence[float]):
    """Host-side wrapper of :func:`_screen_stats`: returns numpy
    ``(finite bool[N], delta_norm f64[N], cos f64[N])`` for a cohort of
    update trees against their dispatch model."""
    fin, norms, cos = _screen_stats(stack_trees(trees), base,
                                    jnp.asarray(list(weights), jnp.float32))
    return (np.asarray(fin), np.asarray(norms, np.float64),
            np.asarray(cos, np.float64))


def _pad_axis1(arr: np.ndarray, pad: int) -> np.ndarray:
    """Append ``pad`` zero rows along the client axis (axis 1)."""
    z = np.zeros((arr.shape[0], pad) + arr.shape[2:], arr.dtype)
    return np.concatenate([arr, z], axis=1)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class BatchedEngine:
    """Compiled vmap/scan executor for one federation's local rounds.

    One instance per :class:`~repro.federation.simulation.Federation`;
    round functions are cached per (Split, prox) and shape-specialized by
    jit, so steady-state rounds run with zero retracing.
    """

    def __init__(self, model, frozen, plan: Optional[SketchPlan], *,
                 lr: float, batch_size: int, use_channel: bool,
                 use_ssop: bool, prox_mu: float = PROX_MU,
                 pad_cohorts: bool = True, mesh: Optional[Mesh] = None,
                 head_lr: Optional[float] = None, clip_norm: float = 0.0):
        self.model = as_split_model(model)
        self.cfg = self.model.cfg
        self.frozen = frozen
        self.plan = plan
        self.lr = lr
        self.head_lr = head_lr       # None -> lr (single-group legacy)
        self.clip_norm = clip_norm   # 0 -> no per-client gradient clipping
        self.batch_size = batch_size
        self.use_channel = use_channel
        self.use_ssop = use_ssop
        self.prox_mu = prox_mu
        self.pad_cohorts = pad_cohorts
        self.mesh = mesh
        self.platform = placement_platform(mesh)
        self.donate = donate_buffers(self.platform)
        self.n_shards = 1
        if mesh is not None:
            if "clients" not in mesh.shape:
                # a pod-only match (e.g. the multi-pod production mesh)
                # would silently replicate every stack across the other
                # axes' devices, so require the real federation axis
                raise ValueError(
                    "federation mesh needs a 'clients' axis; got axes "
                    f"{tuple(mesh.shape)} — build it with "
                    "repro.launch.mesh.make_federation_mesh")
            axes = client_axes(mesh)
            for a in axes:
                self.n_shards *= mesh.shape[a]
            spec = axes[0] if len(axes) == 1 else axes
            # leading client axis split across devices; step axis of the
            # (steps, N, ...) batch stacks stays unsharded
            self._shard_clients = NamedSharding(mesh, PartitionSpec(spec))
            self._shard_batches = NamedSharding(mesh,
                                                PartitionSpec(None, spec))
            self._replicate = NamedSharding(mesh, PartitionSpec())
            # frozen split-model params are read-only every round:
            # replicate them once up front
            self.frozen = jax.device_put(frozen, self._replicate)
        self._round_fns: Dict = {}
        if tm.enabled():
            tm.set_gauge("engine.donate_buffers", float(self.donate),
                         platform=self.platform)
            tm.set_gauge("engine.n_shards", float(self.n_shards),
                         platform=self.platform)

    # -- compiled round function per split configuration -------------------
    def _round_fn(self, split: Split, prox: bool):
        key = (split, prox)
        if key in self._round_fns:
            return self._round_fns[key]

        model, plan = self.model, self.plan
        lr, mu = self.lr, self.prox_mu
        head_lr, clip_norm = self.head_lr, self.clip_norm
        with_ssop = self.use_channel and self.use_ssop
        chan_plan = plan if self.use_channel else None

        def per_client(frozen, lora, ssop, tok, lab, wt):
            channel = Channel(ssop if with_ssop else None, chan_plan)
            batch = {"tokens": tok, "labels": lab, "weights": wt}
            return jax.value_and_grad(
                lambda lp: weighted_split_loss(model, frozen, lp, batch,
                                               split, channel))(lora)

        def round_fn(frozen, lora_stack, ssop_stack, anchor,
                     tokens, labels, weights):
            ssop_axis = 0 if ssop_stack is not None else None
            # per-leaf python-float lrs (adapter vs head groups); with
            # head_lr=None every leaf is exactly `lr`, so the update
            # below stays bit-identical to the historical `p - lr * g`
            lrs = adapter_head_lr_tree(lora_stack, lr, head_lr)

            def step(stack, xs):
                tok, lab, wt = xs
                losses, grads = jax.vmap(
                    per_client,
                    in_axes=(None, 0, ssop_axis, 0, 0, 0))(
                        frozen, stack, ssop_stack, tok, lab, wt)
                if prox:
                    grads = fedprox_gradient(grads, stack, anchor, mu)
                if clip_norm > 0:
                    # per-client global-norm clip along the stacked axis
                    grads = jax.vmap(
                        lambda g: clip_by_global_norm(g, clip_norm))(grads)
                stack = jax.tree_util.tree_map(
                    lambda p, g, s: p - s * g, stack, grads, lrs)
                return stack, losses

            final, losses = jax.lax.scan(step, lora_stack,
                                         (tokens, labels, weights))
            return final, losses          # losses: (steps, N)

        # donate the stacked LoRA buffers (in-place round update) when the
        # arrays' actual placement supports it — gate on where the stacks
        # live (mesh devices when sharding), not the process default
        # backend, which can disagree with the placement
        fn = jax.jit(round_fn, donate_argnums=(1,) if self.donate else ())
        self._round_fns[key] = fn
        return fn

    def compile_cache_sizes(self) -> Dict[Tuple[Split, bool], int]:
        """Compiled-executable count per (split, prox) round function —
        how many distinct cohort shapes each has specialized for."""
        return {k: fn._cache_size() for k, fn in self._round_fns.items()}

    # -- public API --------------------------------------------------------
    def run_clients(self, theta, clients: Sequence[int],
                    splits: Dict[int, Split], channels: Dict[int, Channel],
                    batches: Dict[int, List[Tuple[np.ndarray, np.ndarray]]],
                    prox_anchor=None,
                    per_client_theta: Optional[bool] = None
                    ) -> Dict[int, Tuple[object, float]]:
        """Run one local round for every client, batched per split bucket.

        ``theta`` is one shared LoRA tree broadcast to every client, or
        a ``{client: tree}`` dict of per-client starting points (the
        fused cross-group dispatch stacks clients that carry different
        edge models into one round).  Callers that know which form they
        pass should say so via ``per_client_theta``; the default sniffs
        the dict's key types (:func:`is_client_map`), which is only safe
        while no registered model's LoRA pytree is integer-keyed.
        ``batches[n]`` is the client's pre-drawn list of ``steps``
        (tokens, labels) batches (its iterator order is preserved).
        ``channels`` maps each cohort slot to the channel of the
        *identity* occupying it this round (``Federation.group_steps``
        resolves occupants through the population's identity-keyed
        channel LRU; without a population, identity == slot) — the
        engine stacks whatever per-slot SS-OPs it is handed, so the
        privacy rotation inside a compiled bucket follows the client,
        not the slot index.
        Returns ``{client: (updated lora tree, mean local loss)}``; the
        loss arrays of all buckets are fetched in a single host sync.
        Buckets are padded up to the next :data:`BUCKET_LADDER` size with
        zero-weight phantom clients (exactly-zero loss and gradients),
        so varying cohort sizes hit a bounded set of compiled shapes.
        With a mesh, bucket sizes are additionally multiples of the
        client-axis extent and every client-stacked input is placed with
        its leading axis sharded across the mesh.
        """
        per_client = (is_client_map(theta) if per_client_theta is None
                      else per_client_theta)
        buckets: Dict[Split, List[int]] = {}
        for n in clients:
            buckets.setdefault(splits[n], []).append(n)
        if self.mesh is not None and prox_anchor is not None:
            prox_anchor = jax.device_put(prox_anchor, self._replicate)

        pending = []
        for split, members in buckets.items():
            toks, labs, wts = stack_padded_batches(
                [batches[n] for n in members], self.batch_size)
            n_real = len(members)
            size = (bucket_size(n_real, self.n_shards) if self.pad_cohorts
                    else -(-n_real // self.n_shards) * self.n_shards)
            if size > n_real:
                pad = size - n_real
                toks = _pad_axis1(toks, pad)
                labs = _pad_axis1(labs, pad)
                wts = _pad_axis1(wts, pad)   # zero weights: inert rows
            if per_client:
                # per-client starting points; phantom rows repeat the
                # last member (zero weights keep them inert)
                trees = [theta[n] for n in members]
                trees += [theta[members[-1]]] * (size - n_real)
                lora_stack = stack_trees(trees)
            else:
                lora_stack = broadcast_tree(theta, size)
            ssop_stack = None
            if self.use_channel and self.use_ssop:
                ssops = [channels[n].ssop for n in members]
                ssops += [ssops[-1]] * (size - n_real)   # phantom rows
                ssop_stack = stack_ssops(ssops)
            if self.mesh is not None:
                lora_stack = jax.device_put(lora_stack, self._shard_clients)
                if ssop_stack is not None:
                    ssop_stack = jax.device_put(ssop_stack,
                                                self._shard_clients)
                toks, labs, wts = jax.device_put(
                    (toks, labs, wts), self._shard_batches)
            else:
                toks, labs, wts = (jnp.asarray(toks), jnp.asarray(labs),
                                   jnp.asarray(wts))
            fn = self._round_fn(split, prox_anchor is not None)
            if tm.enabled():
                # compile-vs-execute accounting: the jit cache growing
                # across this dispatch means a fresh trace+compile for
                # this (split, cohort-bucket) shape; steady state stays
                # at one executable per (split, bucket)
                lbl = f"p{split.p}q{split.q}o{split.o}"
                prox_l = prox_anchor is not None
                before = fn._cache_size()
                t0 = time.perf_counter()
                out_stack, losses = fn(self.frozen, lora_stack,
                                       ssop_stack, prox_anchor,
                                       toks, labs, wts)
                dur = time.perf_counter() - t0
                compiled = fn._cache_size() > before
                if compiled:
                    tm.inc("engine.jit_compiles", 1, split=lbl,
                           bucket=size, prox=prox_l)
                tm.observe("engine.dispatch_s", dur, compiled=compiled)
                tm.inc("engine.clients", n_real)
                tm.inc("engine.phantom_rows", size - n_real)
                tm.set_gauge("engine.compile_cache", fn._cache_size(),
                             split=lbl, prox=prox_l)
            else:
                out_stack, losses = fn(self.frozen, lora_stack,
                                       ssop_stack, prox_anchor,
                                       toks, labs, wts)
            pending.append((members, out_stack, losses))

        # one host sync for every bucket's (steps, N) loss array
        loss_host = jax.device_get([l for (_, _, l) in pending])
        results: Dict[int, Tuple[object, float]] = {}
        for (members, out_stack, _), ls in zip(pending, loss_host):
            per_client = ls.mean(axis=0)                     # (N,)
            for i, n in enumerate(members):
                results[n] = (index_tree(out_stack, i), float(per_client[i]))
        return results
