from repro.serving.engine import GenerationRequest, ServingEngine  # noqa: F401
