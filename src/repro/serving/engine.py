"""Batched generation engine over the zoo decode path.

Tick-synchronous static batching: requests queue up, a full batch is
admitted at a tick boundary (left-aligned, prompts consumed token-by-token
through the same jitted step that decodes — "piggyback prefill"), EOS /
max-new-token termination per slot, throughput accounting.  Positions stay
uniform across the batch (our KV caches carry one write cursor), which is
what the decode dry-run shapes lower; per-slot cursors (continuous
batching) are future work and would need per-element cache scatter.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry as tm
from repro.configs.base import ArchConfig
from repro.models import zoo
from repro.models.params import init_tree


@dataclasses.dataclass
class GenerationRequest:
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine
    request_id: int = -1
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0
    finished_at: float = 0.0


class ServingEngine:
    """batch_size requests generate in lock-step; next batch starts when
    every slot finishes (static batching)."""

    def __init__(self, cfg: ArchConfig, params=None, *, batch_size: int = 4,
                 max_len: int = 128, seed: int = 0, greedy: bool = True):
        self.cfg = cfg
        self.model = zoo.get_model(cfg)
        if self.model.decode_step is None:
            raise ValueError(f"{cfg.name} has no decode path")
        self.batch_size = batch_size
        self.max_len = max_len
        if params is None:
            params = init_tree(self.model.specs(cfg),
                               jax.random.PRNGKey(seed), cfg.dtype())
        self.frozen, self.lora = params["frozen"], params["lora"]
        self.queue: deque = deque()
        self._next_id = 0
        self.stats = {"requests": 0, "tokens": 0, "ticks": 0,
                      "decode_s": 0.0}

        def step(frozen, lora, cache, tokens):
            logits, new_cache = self.model.decode_step(
                cfg, frozen, lora, cache, {"tokens": tokens},
                window=cfg.sliding_window)
            nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
            return nxt.astype(jnp.int32), new_cache

        self._step = jax.jit(step)

    # ------------------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> GenerationRequest:
        req = GenerationRequest(prompt=list(prompt),
                                max_new_tokens=max_new_tokens,
                                eos_id=eos_id, request_id=self._next_id,
                                submitted_at=time.time())
        self._next_id += 1
        self.queue.append(req)
        return req

    def swap_adapter(self, lora) -> None:
        """Hot-swap the serving LoRA (e.g. after a cloud fusion): the
        jitted decode step re-runs with the new weights on its next tick
        without recompiling (same shapes), so a federation can push
        fused adapters into a live engine between batches."""
        self.lora = lora
        tm.inc("serving.adapter_swaps", 1)

    def _fresh_cache(self):
        return init_tree(self.model.cache_specs(self.cfg, self.batch_size,
                                                self.max_len),
                         jax.random.PRNGKey(1), self.cfg.dtype())

    # ------------------------------------------------------------------
    def run_batch(self) -> List[GenerationRequest]:
        """Admit up to batch_size queued requests and run them to
        completion.  Returns the finished requests."""
        batch: List[GenerationRequest] = []
        while self.queue and len(batch) < self.batch_size:
            batch.append(self.queue.popleft())
        if not batch:
            return []
        b = self.batch_size
        cache = self._fresh_cache()

        prompts = [r.prompt for r in batch]
        max_prompt = max(len(p) for p in prompts)
        max_new = max(r.max_new_tokens for r in batch)
        horizon = min(max_prompt + max_new, self.max_len)

        cur = np.zeros((b,), np.int64)                # per-slot token index
        tok = np.zeros((b, 1), np.int32)
        for i, p in enumerate(prompts):
            tok[i, 0] = p[0]
        active = np.array([i < len(batch) for i in range(b)])

        t0 = time.time()
        for t in range(1, horizon):
            nxt, cache = self._step(self.frozen, self.lora,
                                    cache, jnp.asarray(tok))
            nxt = np.asarray(nxt)
            self.stats["ticks"] += 1
            for i, r in enumerate(batch):
                if not active[i]:
                    continue
                if t < len(r.prompt):
                    tok[i, 0] = r.prompt[t]           # still consuming prompt
                else:
                    gen = int(nxt[i])
                    r.output.append(gen)
                    self.stats["tokens"] += 1
                    tok[i, 0] = gen
                    if ((r.eos_id is not None and gen == r.eos_id)
                            or len(r.output) >= r.max_new_tokens):
                        r.done = True
                        r.finished_at = time.time()
                        active[i] = False
            if not active[: len(batch)].any():
                break
        self.stats["decode_s"] += time.time() - t0
        for r in batch:
            if not r.done:
                r.done = True
                r.finished_at = time.time()
            self.stats["requests"] += 1
        if tm.enabled():
            for r in batch:
                tm.observe("serving.request_s",
                           max(r.finished_at - r.submitted_at, 0.0))
            tm.inc("serving.requests", len(batch))
            tm.inc("serving.tokens",
                   sum(len(r.output) for r in batch))
        return batch

    def run_until_drained(self) -> List[GenerationRequest]:
        out: List[GenerationRequest] = []
        while self.queue:
            out.extend(self.run_batch())
        return out

    # ------------------------------------------------------------------
    def throughput(self) -> Dict[str, float]:
        dt = max(self.stats["decode_s"], 1e-9)
        return {"tokens_per_s": self.stats["tokens"] / dt,
                "requests": float(self.stats["requests"]),
                "ticks": float(self.stats["ticks"])}
