"""Optimizers (no optax): AdamW, SGD+momentum, FedProx proximal wrapper,
FedAdam/FedAMS server optimizers, global-norm clipping, LR schedules."""
from repro.optim.optimizers import (AdamW, SGD, FedAdam, FedProx, FedAMS,
                                    Optimizer, clip_by_global_norm,
                                    fedprox_gradient,
                                    global_norm)  # noqa: F401
from repro.optim.schedules import (adapter_head_lr_tree, constant,
                                   cosine_decay, warmup_cosine)  # noqa: F401
