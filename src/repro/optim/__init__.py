"""Optimizers (no optax): AdamW, SGD+momentum, FedProx proximal wrapper,
FedAMS server optimizer, LR schedules."""
from repro.optim.optimizers import (AdamW, SGD, FedProx, FedAMS,
                                    Optimizer, fedprox_gradient)  # noqa: F401
from repro.optim.schedules import (constant, cosine_decay,
                                   warmup_cosine)  # noqa: F401
