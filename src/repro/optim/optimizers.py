"""Pure-JAX optimizers over parameter pytrees."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer:
    """Interface: init(params) -> state; update(params, grads, state) ->
    (new_params, new_state)."""

    def init(self, params):
        raise NotImplementedError

    def update(self, params, grads, state):
        raise NotImplementedError


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def global_norm(tree) -> jnp.ndarray:
    """L2 norm over every leaf of a pytree (f32+ accumulation)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(l.astype(jnp.promote_types(l.dtype,
                                                      jnp.float32))))
        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    """Scale ``grads`` so its global L2 norm is at most ``max_norm``.

    Direction-preserving (one shared scale across all leaves), a no-op
    when the norm is already under the cap, and safe on all-zero
    gradients (the scale's denominator is guarded, no 0/0 NaN).  The
    split-model gradients have measured parameter-Lipschitz ~1e5, so
    clipping is what lets client steps run at a useful lr without the
    divergence the stable-lr analysis predicts.
    """
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return _tmap(lambda g: (g.astype(jnp.promote_types(g.dtype, jnp.float32))
                            * scale).astype(g.dtype), grads)


def fedprox_gradient(grads, params, anchor, mu: float):
    """FedProx proximal gradient ``g + mu (w - w_anchor)``, leafwise.

    Vectorizes over client-stacked parameter trees: ``params``/``grads``
    may carry a leading client axis (N, ...) while ``anchor`` stays the
    shared global tree — the anchor broadcasts against every client row,
    so the batched federation engine and the sequential reference apply
    the identical proximal term.
    """
    return _tmap(lambda g, p, a: g + mu * (p - a), grads, params, anchor)


@dataclasses.dataclass
class SGD(Optimizer):
    lr: float = 1e-2
    momentum: float = 0.0

    def init(self, params):
        if self.momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {"step": jnp.zeros((), jnp.int32),
                "m": _tmap(jnp.zeros_like, params)}

    def update(self, params, grads, state):
        lr = self.lr
        if self.momentum == 0.0:
            new = _tmap(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
            return new, {"step": state["step"] + 1}
        m = _tmap(lambda mm, g: self.momentum * mm + g.astype(mm.dtype),
                  state["m"], grads)
        new = _tmap(lambda p, mm: p - lr * mm.astype(p.dtype), params, m)
        return new, {"step": state["step"] + 1, "m": m}


@dataclasses.dataclass
class AdamW(Optimizer):
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None

    def init(self, params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "v": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(self, params, grads, state):
        step = state["step"] + 1
        lr = self.lr if self.schedule is None else self.lr * self.schedule(step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)
        m = _tmap(lambda mm, g: self.b1 * mm + (1 - self.b1) * g.astype(jnp.float32),
                  state["m"], grads)
        v = _tmap(lambda vv, g: self.b2 * vv
                  + (1 - self.b2) * jnp.square(g.astype(jnp.float32)),
                  state["v"], grads)

        def upd(p, mm, vv):
            mh = mm / b1c
            vh = vv / b2c
            step_ = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay:
                step_ = step_ + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step_).astype(p.dtype)

        new = _tmap(upd, params, m, v)
        return new, {"step": step, "m": m, "v": v}


@dataclasses.dataclass
class FedProx(Optimizer):
    """SGD with the FedProx proximal term mu/2 ||w - w_global||^2
    [Li et al., MLSys 2020]: g <- g + mu (w - w_global)."""
    lr: float = 1e-2
    mu: float = 0.01

    def init(self, params):
        return {"step": jnp.zeros((), jnp.int32),
                "anchor": _tmap(lambda p: p, params)}

    def set_anchor(self, state, anchor):
        return {**state, "anchor": anchor}

    def update(self, params, grads, state):
        new = _tmap(
            lambda p, g, a: p - self.lr * (g.astype(p.dtype)
                                           + self.mu * (p - a)),
            params, grads, state["anchor"])
        return new, {**state, "step": state["step"] + 1}


@dataclasses.dataclass
class FedAdam(Optimizer):
    """Server-side adaptive aggregation (FedOpt family, Reddi et al.,
    ICLR 2021) with *bias-corrected* moments: ``update`` treats
    ``grads`` as the pseudo-gradient (old_global - aggregated).

    Defaults follow the convergence study (docs/convergence.md): a
    small server lr with a fat adaptivity floor ``tau`` — the FedAMS
    default of lr=1.0 diverges on the split-LoRA task, whereas a
    bias-corrected lr≈0.03–0.1 step on the same pseudo-gradients is
    what turns the server step from destabilizing into a rescue
    (FedSEA-LLaMA, arXiv:2505.15683, makes the same observation for
    split-LLM federation).
    """
    lr: float = 0.05
    b1: float = 0.9
    b2: float = 0.99
    tau: float = 1e-3      # adaptivity floor (Reddi et al.'s tau)

    def init(self, params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "v": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(self, params, grads, state):
        step = state["step"] + 1
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)
        m = _tmap(lambda mm, g: self.b1 * mm
                  + (1 - self.b1) * g.astype(jnp.float32),
                  state["m"], grads)
        v = _tmap(lambda vv, g: self.b2 * vv
                  + (1 - self.b2) * jnp.square(g.astype(jnp.float32)),
                  state["v"], grads)
        new = _tmap(lambda p, mm, vv:
                    (p.astype(jnp.float32)
                     - self.lr * (mm / b1c)
                     / (jnp.sqrt(vv / b2c) + self.tau)).astype(p.dtype),
                    params, m, v)
        return new, {"step": step, "m": m, "v": v}


@dataclasses.dataclass
class FedAMS(Optimizer):
    """Server-side adaptive aggregation with AMSGrad-style max-v
    [Wang et al., ICML 2022].  ``update`` treats ``grads`` as the
    pseudo-gradient (old_global - aggregated)."""
    lr: float = 1.0
    b1: float = 0.9
    b2: float = 0.99
    eps: float = 1e-3

    def init(self, params):
        z = _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"step": jnp.zeros((), jnp.int32), "m": z, "v": z,
                "vmax": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(self, params, grads, state):
        m = _tmap(lambda mm, g: self.b1 * mm + (1 - self.b1) * g.astype(jnp.float32),
                  state["m"], grads)
        v = _tmap(lambda vv, g: self.b2 * vv
                  + (1 - self.b2) * jnp.square(g.astype(jnp.float32)),
                  state["v"], grads)
        vmax = _tmap(jnp.maximum, state["vmax"], v)
        new = _tmap(lambda p, mm, vm:
                    (p.astype(jnp.float32)
                     - self.lr * mm / (jnp.sqrt(vm) + self.eps)).astype(p.dtype),
                    params, m, vmax)
        return new, {"step": state["step"] + 1, "m": m, "v": v, "vmax": vmax}
