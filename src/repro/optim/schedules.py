"""Learning-rate schedules (multiplicative factors on the base lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant():
    return lambda step: jnp.ones((), jnp.float32)


def cosine_decay(total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return final_frac + (1.0 - final_frac) * cos
    return f


def warmup_cosine(warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    cd = cosine_decay(max(total_steps - warmup_steps, 1), final_frac)
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup_steps, 1)
        return jnp.where(s < warmup_steps, warm, cd(step - warmup_steps))
    return f
