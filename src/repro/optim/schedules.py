"""Learning-rate schedules (multiplicative factors on the base lr) and
per-parameter-group learning rates."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def adapter_head_lr_tree(lora_like, lr: float,
                         head_lr: Optional[float] = None):
    """Per-leaf learning rates: adapter vs readout-head groups.

    Every leaf under the top-level ``"blocks"`` (and ``"prefix"``)
    subtrees — the LoRA adapters inside the block stack — gets ``lr``;
    everything else (pooler, classification head, any readout parameter
    outside the stack) gets ``head_lr`` (default: ``lr``).  Leaves are
    exact python floats, so with ``head_lr=None`` the update
    ``p - lr_leaf * g`` is bit-identical to the historical scalar
    ``p - lr * g``.
    """
    hl = lr if head_lr is None else head_lr
    if not isinstance(lora_like, dict):
        return jax.tree_util.tree_map(lambda _: lr, lora_like)
    return {k: jax.tree_util.tree_map(
                lambda _: lr if k in ("blocks", "prefix") else hl, v)
            for k, v in lora_like.items()}


def constant():
    return lambda step: jnp.ones((), jnp.float32)


def cosine_decay(total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return final_frac + (1.0 - final_frac) * cos
    return f


def warmup_cosine(warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    cd = cosine_decay(max(total_steps - warmup_steps, 1), final_frac)
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup_steps, 1)
        return jnp.where(s < warmup_steps, warm, cd(step - warmup_steps))
    return f
