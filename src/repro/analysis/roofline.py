"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh):
  compute term    = flops_per_chip / 197e12          [s]   (bf16 peak, v5e)
  memory term     = hbm_bytes_per_chip / 819e9       [s]
  collective term = wire_bytes_per_chip / 50e9       [s]   (ICI per link)

flops / bytes / wire-bytes come from the loop-aware HLO parser
(repro.analysis.hlo_cost); XLA's cost_analysis is recorded alongside for
reference (it under-counts while-loop bodies).

MODEL_FLOPS uses the 6·N·D convention (2·N·D forward-only for prefill;
2·N_active·B per decoded token), N excluding embedding/vocab tables and
counting only the active expert fraction for MoE.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, Optional

import numpy as np
import zstandard

from repro.analysis.hlo_cost import analyze, Cost
from repro.configs import get_config
from repro.configs.base import INPUT_SHAPES
from repro.models import zoo
from repro.models.params import Spec, is_spec

PEAK_FLOPS = 197e12      # bf16 / chip (v5e)
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link


def active_params(cfg) -> float:
    """Parameter count excluding vocab tables; MoE experts scaled by the
    routed fraction (top-k / E); shared experts fully counted."""
    import jax
    specs = zoo.get_model(cfg).specs(cfg)
    total = 0.0
    frac = 1.0
    if cfg.moe:
        frac = cfg.moe.experts_per_token / cfg.moe.num_experts

    def visit(path, node):
        nonlocal total
        if is_spec(node):
            if "vocab" in (node.axes or ()):
                return
            n = float(np.prod(node.shape))
            if "experts" in (node.axes or ()):
                n *= frac
            total += n
            return
        if isinstance(node, dict):
            for k, v in node.items():
                visit(path + (k,), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                visit(path + (str(i),), v)

    visit((), specs)
    return total


def model_flops(cfg, shape) -> float:
    n = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: 1 token


def load_record(json_path: str) -> Optional[Dict]:
    with open(json_path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return rec
    hlo_path = json_path.replace(".json", ".hlo.zst")
    if os.path.exists(hlo_path):
        with open(hlo_path, "rb") as f:
            text = zstandard.ZstdDecompressor().decompress(
                f.read(), max_output_size=1 << 31).decode()
        cost = analyze(text)
        rec["parsed"] = {
            "flops_per_chip": cost.flops,
            "bytes_per_chip": cost.bytes,
            "collectives": dict(cost.collective_bytes),
            "wire_bytes_per_chip": cost.total_collective_bytes,
        }
    return rec


def roofline_terms(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok" or "parsed" not in rec:
        return None
    p = rec["parsed"]
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    chips = rec["chips"]
    t_c = p["flops_per_chip"] / PEAK_FLOPS
    t_m = p["bytes_per_chip"] / HBM_BW
    t_n = p["wire_bytes_per_chip"] / ICI_BW
    dominant = max((("compute", t_c), ("memory", t_m), ("collective", t_n)),
                   key=lambda x: x[1])[0]
    mf = model_flops(cfg, shape)
    hlo_global = p["flops_per_chip"] * chips
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "step_s": max(t_c, t_m, t_n),
    }


_SUGGEST = {
    "compute": ("compute-bound: raise MXU utilization (larger tiles, bf16 "
                "throughout) or cut redundant recompute (remat policy)"),
    "memory": ("HBM-bound: shrink the working set (fuse the channel ops, "
               "smaller attention chunks, bf16 intermediates) or raise "
               "arithmetic intensity per pass"),
    "collective": ("ICI-bound: reshard to cut cross-slice traffic (delayed "
                   "pod sync for LoRA, expert-parallel all-to-all instead "
                   "of replicated experts, overlap collectives with "
                   "compute)"),
}


def make_table(records, *, mesh_filter="pod256", tag_filter="") -> str:
    rows = []
    for rec in records:
        if rec.get("mesh") != mesh_filter or rec.get("tag", "") != tag_filter:
            continue
        arch, shape = rec["arch"], rec["shape"]
        if rec["status"] == "skipped":
            rows.append(f"| {arch} | {shape} | skipped | — | — | — | — | — | "
                        f"{rec['reason'][:60]} |")
            continue
        t = roofline_terms(rec)
        if t is None:
            rows.append(f"| {arch} | {shape} | {rec['status']} | | | | | | |")
            continue
        rows.append(
            f"| {arch} | {shape} | ok | {t['compute_s']*1e3:.2f} | "
            f"{t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} | "
            f"**{t['dominant']}** | {t['useful_ratio']:.2f} | "
            f"{_SUGGEST[t['dominant']][:80]}… |")
    header = ("| arch | shape | status | compute (ms) | memory (ms) | "
              "collective (ms) | dominant | 6ND/HLO | next lever |\n"
              "|---|---|---|---|---|---|---|---|---|")
    return header + "\n" + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "runs", "dryrun"))
    ap.add_argument("--mesh", default="pod256")
    ap.add_argument("--tag", default="")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    records = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        rec = load_record(path)
        if rec:
            t = roofline_terms(rec)
            if t:
                rec["roofline"] = t
            records.append(rec)
    print(make_table(records, mesh_filter=args.mesh, tag_filter=args.tag))
    if args.json_out:
        slim = [{k: v for k, v in r.items() if k != "traceback"}
                for r in records]
        with open(args.json_out, "w") as f:
            json.dump(slim, f, indent=2, default=float)


if __name__ == "__main__":
    main()
