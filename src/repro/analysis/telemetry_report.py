"""Human-readable report over a telemetry JSONL file
(docs/observability.md).

Renders the merged run summary written by :func:`repro.telemetry.
export_jsonl` — per-phase wall/simulated time, the simulated comm
breakdown (seconds + wire bytes), runtime event counts, engine compile
accounting, screening verdicts, and histogram digests — as one plain
table, either from a finished file's summary line or rebuilt from the
round records of a killed run.

Usage: PYTHONPATH=src python -m repro.analysis.telemetry_report \\
           runs/telemetry.jsonl [--rounds]
"""
from __future__ import annotations

import argparse
from typing import Any, Dict, List

from repro.telemetry import read_jsonl

# round-lifecycle phases, in execution order (other span names render
# after these, alphabetically)
PHASES = ("profile", "dispatch", "local_steps", "uplink", "edge_agg",
          "cloud_agg", "eval")

# simulated per-dispatch cost counters -> display label
SIM_COUNTERS = (("runtime.sim.compute_s", "compute"),
                ("runtime.sim.uplink_s", "uplink"),
                ("runtime.sim.downlink_s", "downlink"),
                ("runtime.sim.latency_s", "latency"))


def _fmt_s(v: float) -> str:
    return f"{v:10.3f}s"


def _fmt_bytes(v: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(v) < 1024.0 or unit == "GiB":
            return f"{v:8.1f}{unit}"
        v /= 1024.0
    return f"{v:8.1f}GiB"


def _series(counters: Dict[str, float], name: str) -> Dict[str, float]:
    """All ``name`` / ``name{...}`` series in a flat counter dict."""
    prefix = name + "{"
    return {k: v for k, v in counters.items()
            if k == name or k.startswith(prefix)}


def render(data: Dict[str, Any], show_rounds: bool = False) -> str:
    """Format one parsed telemetry file (:func:`read_jsonl` output)."""
    s = data["summary"]
    counters: Dict[str, float] = s.get("counters", {})
    lines: List[str] = []
    meta = s.get("meta") or {}
    head = ", ".join(f"{k}={v}" for k, v in sorted(meta.items()))
    lines.append(f"telemetry summary ({s.get('rounds', 0)} rounds"
                 + (f"; {head}" if head else "") + ")")

    spans: Dict[str, Dict[str, float]] = s.get("spans", {})
    if spans:
        lines.append("")
        lines.append("phase            count       wall         sim")
        ordered = [p for p in PHASES if p in spans] \
            + sorted(k for k in spans if k not in PHASES)
        for name in ordered:
            agg = spans[name]
            lines.append(f"{name:<14} {int(agg['count']):7d} "
                         f"{_fmt_s(agg['wall_s'])} "
                         f"{_fmt_s(agg['sim_s'])}")

    sim_rows = [(lbl, counters.get(key, 0.0)) for key, lbl in SIM_COUNTERS
                if key in counters]
    if sim_rows:
        total = sum(v for _, v in sim_rows)
        lines.append("")
        lines.append("simulated cost      seconds    share")
        for lbl, v in sim_rows:
            lines.append(f"{lbl:<14} {_fmt_s(v)}   "
                         f"{v / max(total, 1e-12) * 100:5.1f}%")
        up = counters.get("runtime.uplink_bytes", 0.0)
        down = counters.get("runtime.downlink_bytes", 0.0)
        if up or down:
            lines.append(f"wire: uplink {_fmt_bytes(up).strip()}, "
                         f"downlink {_fmt_bytes(down).strip()}")

    events = _series(counters, "runtime.events")
    if events:
        lines.append("")
        lines.append("runtime events")
        for k in sorted(events):
            kind = k[k.find("kind=") + 5:-1] if "{" in k else k
            lines.append(f"  {kind:<12} {int(events[k]):7d}")

    compiles = _series(counters, "engine.jit_compiles")
    if compiles:
        lines.append("")
        lines.append(f"engine: {int(sum(compiles.values()))} jit compiles, "
                     f"{int(counters.get('engine.clients', 0))} client "
                     f"dispatches, "
                     f"{int(counters.get('engine.phantom_rows', 0))} "
                     f"phantom rows")
        for k in sorted(compiles):
            lines.append(f"  {k:<48} {int(compiles[k]):4d}")

    verdicts = _series(counters, "screening.verdicts")
    if verdicts:
        lines.append("")
        lines.append("screening verdicts")
        for k in sorted(verdicts):
            v = k[k.find("verdict=") + 8:-1] if "{" in k else k
            lines.append(f"  {v:<12} {int(verdicts[k]):7d}")

    hists = s.get("histograms", {})
    if hists:
        lines.append("")
        lines.append("histograms          count        mean         max")
        for k in sorted(hists):
            h = hists[k]
            n = h.get("count", 0)
            mean = h.get("sum", 0.0) / max(n, 1)
            mx = h.get("max")
            lines.append(f"{k:<44} {n:6d} {mean:11.4f} "
                         f"{mx if mx is not None else float('nan'):11.4f}")

    if show_rounds:
        lines.append("")
        lines.append("round     sim_time    spans  counter-deltas")
        for rec in data["rounds"]:
            g = rec.get("round")
            t = rec.get("sim_time_s")
            lines.append(f"{str(g):>5} "
                         f"{t if t is not None else float('nan'):11.2f} "
                         f"{len(rec.get('spans', ())):7d} "
                         f"{len(rec.get('counters', {})):7d}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Render a telemetry JSONL file as a phase/cost report")
    ap.add_argument("path", help="telemetry .jsonl written by "
                                 "repro.telemetry.export")
    ap.add_argument("--rounds", action="store_true",
                    help="append the per-round record table")
    args = ap.parse_args()
    print(render(read_jsonl(args.path), show_rounds=args.rounds))


if __name__ == "__main__":
    main()
