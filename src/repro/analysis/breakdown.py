"""Top-contributor breakdown over a dry-run HLO artifact: which
instructions (x loop trip multipliers) dominate flops / bytes / wire.

Usage: PYTHONPATH=src python -m repro.analysis.breakdown <record-name> [--top 15]
"""
from __future__ import annotations

import argparse
import os
import re
from collections import defaultdict

import zstandard

from repro.analysis import hlo_cost as H

RUNS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "runs",
                    "dryrun")


def breakdown(text: str):
    mc = H.ModuleCost(text)
    rows = []

    def walk(comp_name: str, mult: float, bytes_visible: bool):
        comp = mc.comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                body = H._CALL_ATTR.search(ins.attrs)
                cond = H._COND_ATTR.search(ins.attrs)
                trips = H._trip_count(mc.comps, cond.group(1)) if cond else 1
                if body:
                    walk(body.group(1), mult * trips, True)
                continue
            if op in ("fusion", "call"):
                called = H._CALL_ATTR.search(ins.attrs)
                if called:
                    walk(called.group(1), mult, False)
                if bytes_visible:
                    if op == "fusion" and called:
                        b = mc._fusion_bytes(ins, comp, called.group(1))
                    else:
                        b = mc._operand_bytes(ins, comp)
                    meta = re.search(r'op_name="([^"]+)"', ins.raw)
                    rows.append((b * mult, 0.0, 0.0,
                                 meta.group(1)[-90:] if meta else ins.name,
                                 ins.result_shape[:40], mult))
                continue
            c = mc._instr_cost(ins, comp, bytes_visible)
            if c.flops or c.bytes or c.collective_bytes:
                meta = re.search(r'op_name="([^"]+)"', ins.raw)
                rows.append((c.bytes * mult, c.flops * mult,
                             c.total_collective_bytes * mult,
                             meta.group(1)[-90:] if meta else ins.name,
                             ins.result_shape[:40], mult))

    walk(mc.entry, 1.0, True)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("record")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--by", choices=["bytes", "flops", "wire"],
                    default="bytes")
    args = ap.parse_args()
    path = os.path.join(RUNS, args.record + ".hlo.zst")
    text = zstandard.ZstdDecompressor().decompress(
        open(path, "rb").read(), max_output_size=1 << 31).decode()
    rows = breakdown(text)
    key = {"bytes": 0, "flops": 1, "wire": 2}[args.by]
    rows.sort(key=lambda r: -r[key])
    total = sum(r[key] for r in rows)
    print(f"total {args.by}: {total:.3e}")
    shown = 0.0
    for r in rows[:args.top]:
        shown += r[key]
        print(f"{r[key]:.3e} ({r[key]/max(total,1e-9)*100:5.1f}%) x{r[5]:<6.0f}"
              f" {r[4]:40s} {r[3]}")
    print(f"(top {args.top} = {shown/max(total,1e-9)*100:.1f}%)")


if __name__ == "__main__":
    main()
