"""Loop-aware cost model over optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which
under-counts scanned layer stacks by the trip count (verified empirically:
a 10-step scanned matmul reports exactly 1/10 the flops of its unrolled
twin).  This parser walks the computation graph, multiplies while bodies
by their statically-derived trip counts, attributes flops to dots (with
dot_dimension_numbers), bytes to top-level operand/result traffic (fusion
internals are free), and collects per-category collective payloads.

All shapes in the post-SPMD module are per-device, so every figure this
module reports is per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 0.5, "u4": 0.5, "token": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# wire factor: ring all-reduce moves ~2x the payload; others ~1x
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exp", "log", "tanh", "negate", "abs", "sqrt", "rsqrt",
    "sign", "floor", "ceil", "cosine", "sine", "logistic", "expm1",
    "log-plus-one", "and", "or", "xor", "not", "select", "compare",
    "clamp", "remainder", "atan2", "cbrt", "round-nearest-afz",
    "round-nearest-even", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "stochastic-convert", "erf",
}

_ZERO_BYTES = {
    "get-tuple-element", "tuple", "bitcast", "parameter", "constant",
    "after-all", "partition-id", "replica-id", "iota",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")
def _parse_header(line: str):
    """'%name (params...) -> shape {' -> (name, params_str) or None.
    Params may contain nested parens (tuple types)."""
    s = line.strip()
    if s.startswith("ENTRY"):
        s = s[len("ENTRY"):].strip()
    if s.startswith("%"):
        s = s[1:]
    m = re.match(r"([\w\.\-]+)\s+\(", s)
    if not m:
        return None
    name = m.group(1)
    depth = 0
    start = m.end() - 1
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                if "->" not in s[i:]:
                    return None
                return name, s[start + 1:i]
    return None


def _shape_bytes(shape_str: str) -> float:
    """Total bytes of all arrays appearing in a shape string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_numel(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_shape: str
    operands: List[str]
    attrs: str
    raw: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, str]     # instr/param name -> result shape string


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) \
                + v * mult

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _split_operands(rest: str) -> Tuple[List[str], str]:
    """Split 'a, b, c), attr=...' at the top-level close paren."""
    depth = 0
    for i, ch in enumerate(rest):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            if depth == 0:
                return re.findall(r"%([\w\.\-]+)", rest[:i]), rest[i + 1:]
            depth -= 1
    return re.findall(r"%([\w\.\-]+)", rest), ""


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and "{" in line and "->" in line:
            hdr = _parse_header(line)
            if hdr:
                name, params = hdr
                cur = Computation(name, [], {})
                # seed params: "param_0.3: f32[10,256,256], arg: (s32[], ...)"
                for pm in re.finditer(
                        r"([\w\.\-]+):\s*(\([^()]*(?:\([^()]*\)[^()]*)*\)|[^,()]+)",
                        params):
                    cur.symbols[pm.group(1)] = pm.group(2)
                comps[cur.name] = cur
            continue
        if cur is None or "=" not in line:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        opm = _OP_RE.search(rhs)
        if not opm:
            continue
        opcode = opm.group(1)
        result_shape = rhs[: opm.start()].strip()
        operands, attrs = _split_operands(rhs[opm.end():])
        cur.instrs.append(Instr(name, opcode, result_shape, operands, attrs,
                                rhs))
        cur.symbols[name] = result_shape
    return comps


def _trip_count(comps, cond_name: str) -> int:
    """Max s32 constant in the loop condition computation (our scans count
    0..N with a `lt` compare against N)."""
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for ins in comp.instrs:
        if ins.opcode == "constant" and ins.result_shape.startswith("s32"):
            m = re.search(r"constant\((\d+)\)", ins.raw)
            if m:
                best = max(best, int(m.group(1)))
    return best


_CALL_ATTR = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_ATTR = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCH_ATTR = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_numel = _shape_numel(ins.result_shape)
    k = 1
    m = _CONTRACT.search(ins.attrs)
    if m and ins.operands:
        lhs_shape = comp.symbols.get(ins.operands[0], "")
        sm = _SHAPE_RE.search(lhs_shape)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for ci in m.group(1).split(","):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_numel * k


class ModuleCost:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self._memo: Dict[str, Cost] = {}
        entry = None
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                hdr = _parse_header(line)
                if hdr:
                    entry = hdr[0]
                break
        if entry is None:  # fall back: last computation
            entry = list(self.comps)[-1]
        self.entry = entry

    def cost(self) -> Cost:
        return self._comp_cost(self.entry)

    def _comp_cost(self, name: str, *, bytes_visible: bool = True) -> Cost:
        key = f"{name}|{bytes_visible}"
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            self._memo[key] = total
            return total
        for ins in comp.instrs:
            total.add(self._instr_cost(ins, comp, bytes_visible))
        self._memo[key] = total
        return total

    def _operand_bytes(self, ins: Instr, comp: Computation) -> float:
        b = _shape_bytes(ins.result_shape)
        for o in ins.operands:
            b += _shape_bytes(comp.symbols.get(o, ""))
        return b

    def _fusion_bytes(self, ins: Instr, comp: Computation,
                      called_name: str) -> float:
        """Fusion boundary traffic with slice-aware accounting: a fusion
        parameter consumed only by dynamic-slice/gather reads only the
        slices; a root dynamic-update-slice is aliased in place (traffic =
        the update, not the whole array)."""
        called = self.comps.get(called_name)
        if called is None:
            return self._operand_bytes(ins, comp)
        # map positional params to outer operand shapes
        param_order = [i2 for i2 in called.instrs if i2.opcode == "parameter"]
        # parameter(N) raw contains the position
        pos_of = {}
        for i2 in param_order:
            m = re.search(r"parameter\((\d+)\)", i2.raw)
            if m:
                pos_of[i2.name] = int(m.group(1))
        consumers: Dict[str, list] = {i2.name: [] for i2 in param_order}
        by_name = {i2.name: i2 for i2 in called.instrs}
        for i2 in called.instrs:
            for o in i2.operands:
                if o in consumers:
                    consumers[o].append(i2)

        def trace_param(name, depth=0):
            """Follow converts/bitcasts/copies back to a fusion param."""
            if name in consumers:
                return name
            if depth > 4 or name not in by_name:
                return None
            i2 = by_name[name]
            if i2.opcode in ("convert", "bitcast", "copy", "reshape") \
                    and i2.operands:
                return trace_param(i2.operands[0], depth + 1)
            return None

        # DUS instrs whose target traces back to a param are in-place
        # (aliased) updates: traffic = the update slice read+write
        dus_list = [i2 for i2 in called.instrs
                    if i2.opcode == "dynamic-update-slice"]
        aliased = set()
        dus_traffic = 0.0
        for d in dus_list:
            tgt = trace_param(d.operands[0]) if d.operands else None
            if tgt is not None:
                aliased.add(tgt)
                if len(d.operands) > 1:
                    upd = called.symbols.get(d.operands[1], "")
                    dus_traffic += 2 * _shape_bytes(upd)

        total = dus_traffic
        for pname, uses in consumers.items():
            if pname in aliased:
                continue
            full = _shape_bytes(called.symbols.get(pname, ""))
            if uses and all(u.opcode in ("dynamic-slice", "gather")
                            for u in uses):
                total += sum(_shape_bytes(u.result_shape) for u in uses)
            else:
                total += full
        root = called.instrs[-1] if called.instrs else None
        root_is_dus = bool(root is not None and (
            root.opcode == "dynamic-update-slice"
            or (root.opcode in ("convert", "bitcast", "copy", "tuple")
                and root.operands and root.operands[0] in by_name
                and by_name[root.operands[0]].opcode
                == "dynamic-update-slice")))
        if not (root_is_dus and aliased):
            total += _shape_bytes(ins.result_shape)
        return total

    def _instr_cost(self, ins: Instr, comp: Computation,
                    bytes_visible: bool) -> Cost:
        op = ins.opcode
        c = Cost()
        if op == "while":
            body = _CALL_ATTR.search(ins.attrs)
            cond = _COND_ATTR.search(ins.attrs)
            trips = _trip_count(self.comps, cond.group(1)) if cond else 1
            if body:
                c.add(self._comp_cost(body.group(1)), trips)
            if cond:
                c.add(self._comp_cost(cond.group(1)), trips)
            return c
        if op == "conditional":
            m = _BRANCH_ATTR.search(ins.attrs)
            if m:
                branches = re.findall(r"%?([\w\.\-]+)", m.group(1))
                costs = [self._comp_cost(b) for b in branches]
                if costs:
                    worst = max(costs, key=lambda x: x.flops + x.bytes)
                    c.add(worst)
            return c
        if op in ("fusion", "call", "custom-call", "map", "reduce",
                  "reduce-window", "sort", "scatter"):
            called = _CALL_ATTR.search(ins.attrs)
            if called:
                # flops from inside; bytes only at the fusion boundary
                inner = self._comp_cost(called.group(1), bytes_visible=False)
                c.flops += inner.flops
                for k, v in inner.collective_bytes.items():
                    c.collective_bytes[k] = c.collective_bytes.get(k, 0) + v
            if op in ("reduce", "reduce-window") and ins.operands:
                c.flops += _shape_numel(
                    comp.symbols.get(ins.operands[0], ""))
            if bytes_visible:
                if op == "fusion" and called:
                    c.bytes += self._fusion_bytes(ins, comp,
                                                  called.group(1))
                else:
                    c.bytes += self._operand_bytes(ins, comp)
            return c
        if op == "dynamic-slice":
            # reads only the slice (result-sized), writes the result
            c.bytes += 2 * _shape_bytes(ins.result_shape)
            return c
        if op == "dynamic-update-slice":
            # in-place aliased on TPU: traffic = the update slice r/w
            upd = (comp.symbols.get(ins.operands[1], "")
                   if len(ins.operands) > 1 else ins.result_shape)
            c.bytes += 2 * _shape_bytes(upd)
            return c
        if op in COLLECTIVES or any(op.startswith(x + "-start")
                                    for x in COLLECTIVES):
            base = op.replace("-start", "")
            payload = max(_shape_bytes(ins.result_shape),
                          sum(_shape_bytes(comp.symbols.get(o, ""))
                              for o in ins.operands))
            c.collective_bytes[base] = c.collective_bytes.get(base, 0.0) \
                + payload * _WIRE_FACTOR.get(base, 1.0)
            if bytes_visible:
                c.bytes += self._operand_bytes(ins, comp)
            return c
        if op == "dot" or op == "convolution":
            c.flops += _dot_flops(ins, comp)
            if bytes_visible:
                c.bytes += self._operand_bytes(ins, comp)
            return c
        if op in _ELEMWISE:
            c.flops += _shape_numel(ins.result_shape)
            if bytes_visible:
                c.bytes += self._operand_bytes(ins, comp)
            return c
        if op in _ZERO_BYTES:
            return c
        # data movement (copy, reshape, transpose, slice, dus, ds, convert,
        # broadcast, pad, concatenate, gather, dynamic-slice, rng, ...)
        if bytes_visible:
            c.bytes += self._operand_bytes(ins, comp)
        return c


def analyze(text: str) -> Cost:
    return ModuleCost(text).cost()
