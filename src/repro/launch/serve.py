"""Batched serving driver: prefill-free greedy decode against a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --steps 16

Uses the same ``make_serve_step`` the decode dry-run shapes lower; reduced
configs on CPU, full configs on accelerators.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_config
from repro.launch.train import make_serve_step
from repro.models import zoo
from repro.models.params import init_tree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=ASSIGNED)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = zoo.get_model(cfg)
    if model.decode_step is None:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    params = init_tree(model.specs(cfg), jax.random.PRNGKey(0), cfg.dtype())
    cache = init_tree(model.cache_specs(cfg, args.batch, args.cache_len),
                      jax.random.PRNGKey(1), cfg.dtype())
    serve = jax.jit(make_serve_step(cfg, window=cfg.sliding_window))

    tok = jax.random.randint(jax.random.PRNGKey(2), (args.batch, 1), 0,
                             cfg.vocab_size)
    # warmup / compile
    nxt, cache = serve(params["frozen"], params["lora"], cache,
                       {"tokens": tok})
    t0 = time.time()
    for _ in range(args.steps):
        nxt, cache = serve(params["frozen"], params["lora"], cache,
                           {"tokens": nxt[:, None]})
    jax.block_until_ready(nxt)
    dt = time.time() - t0
    print(f"{args.arch}: {args.steps} decode steps x batch {args.batch} "
          f"in {dt:.2f}s -> {args.steps * args.batch / dt:.1f} tok/s "
          f"(CPU, reduced={not args.full})")


if __name__ == "__main__":
    main()
