"""Production and federation mesh definitions.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis carries ELSA's hierarchical (edge-group -> cloud) aggregation stage.

Federation mesh: a 1-D ("clients",) mesh (optionally ("pod", "clients"))
over the first N available devices; the batched federation engine shards
its stacked leading client axis across it while the frozen split-model
parameters stay replicated.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_federation_mesh(n_devices: Optional[int] = None, *,
                         pods: int = 1,
                         devices: Optional[Sequence] = None) -> Mesh:
    """Mesh the batched federation engine shards clients across.

    Takes the first ``n_devices`` of ``devices`` (default: all of
    ``jax.devices()``) as a 1-D ``("clients",)`` mesh; ``pods > 1``
    folds them into ``("pod", "clients")`` so the pod axis can carry the
    edge-group -> cloud stage.  On CPU, export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before* the
    first jax import to get 8 host devices to shard across.
    """
    devs = list(devices) if devices is not None else jax.devices()
    n = len(devs) if n_devices is None else n_devices
    if not 1 <= n <= len(devs):
        raise ValueError(f"requested {n} devices, have {len(devs)}")
    if pods > 1:
        if n % pods:
            raise ValueError(f"{n} devices do not fold into {pods} pods")
        grid = np.asarray(devs[:n]).reshape(pods, n // pods)
        return Mesh(grid, ("pod", "clients"))
    return Mesh(np.asarray(devs[:n]), ("clients",))


def data_axes(mesh) -> tuple:
    """The (composite) batch-sharding axes present in this mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def client_axes(mesh) -> tuple:
    """The (composite) stacked-client-sharding axes in this mesh."""
    return tuple(a for a in ("pod", "clients") if a in mesh.shape)


def chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
