"""Production mesh definitions.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis carries ELSA's hierarchical (edge-group -> cloud) aggregation stage.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The (composite) batch-sharding axes present in this mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
