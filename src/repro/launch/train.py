"""Step builders + sharding assembly for training and serving.

``make_train_step`` builds the LoRA fine-tuning step (frozen backbone, the
paper's adapter-only optimization): loss -> grads over the LoRA tree ->
AdamW.  With ``per_pod_lora=True`` the step is vmapped over the "pod" axis
(``spmd_axis_name``) so each pod keeps an independent LoRA replica —
ELSA's hierarchical schedule: edge-level (data-axis) gradient reduction
every step, cloud-level (pod-axis) fusion only at ``cloud_sync`` time.

``make_serve_step`` builds the single-token decode step against a sharded
KV/state cache.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.launch.mesh import data_axes
from repro.models import zoo
from repro.models.params import (abstract_tree, tree_shardings, Spec,
                                 is_spec)
from repro.optim import AdamW


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def elsa_boundaries(cfg) -> tuple:
    """Default tripartite split for an arch: p = min(p_max, L//4),
    o_fix = 2 (ELSA §III.B.2 with the paper's p_max=6)."""
    n = cfg.num_layers - (cfg.moe.first_dense_layers if cfg.moe else 0)
    p = max(1, min(6, n // 4))
    o = 2
    return (p, n - p - o)


def elsa_channel_specs(cfg, *, r: int = 16, y: int = 3,
                       rho: float = 2.1):
    """Abstract channel parameters (SS-OP basis + sketch hashes) shipped
    inside the batch under '_channel' for the dry-run / launcher."""
    import jax as _jax
    d = cfg.d_model
    z = max(8, int(d / (rho * y)))
    return {
        "u": _jax.ShapeDtypeStruct((d, r), jnp.float32),
        "v": _jax.ShapeDtypeStruct((r, r), jnp.float32),
        "bucket": _jax.ShapeDtypeStruct((y, d), jnp.int32),
        "sign": _jax.ShapeDtypeStruct((y, d), jnp.float32),
    }, z


def make_train_step(cfg: ArchConfig, *, optimizer: Optional[AdamW] = None,
                    window: int = 0, chunk: int = 2048,
                    per_pod_lora: bool = False, use_flash: bool = False,
                    num_microbatches: int = 1, elsa_z: int = 0):
    """LoRA fine-tuning step.  ``num_microbatches > 1`` runs gradient
    accumulation over microbatch slices of the global batch (per-microbatch
    activation footprint; LoRA grads are tiny, so the accumulator is
    nearly free).

    If the batch carries a ``'_channel'`` entry (u, v, bucket, sign) and
    ``elsa_z`` is set, the ELSA tripartite split channel is applied at the
    Eq. 8-9 boundaries inside the layer stack (dense/moe families)."""
    model = zoo.get_model(cfg)
    opt = optimizer or AdamW(lr=1e-4)

    def single_loss(frozen, lp, batch, channel_params=None):
        fwd = dict(window=window, chunk=chunk, remat=True)
        if channel_params is not None and cfg.family in ("dense", "moe"):
            from repro.core.sketch import SketchPlan
            from repro.core.split_training import Channel
            from repro.core.ssop import SSOP
            ch = Channel(SSOP(channel_params["u"], channel_params["v"]),
                         SketchPlan(channel_params["bucket"],
                                    channel_params["sign"], elsa_z))
            fwd.update(boundaries=elsa_boundaries(cfg), channel=ch)
        logits, aux = model.forward(cfg, frozen, lp, batch, **fwd)
        if cfg.family == "encoder":
            return zoo.classification_loss(logits, batch["labels"])
        return zoo.loss_fn(cfg, logits, batch["tokens"], aux)

    def core(frozen, lora, opt_state, batch):
        batch = dict(batch)
        channel_params = batch.pop("_channel", None)
        if num_microbatches <= 1:
            loss, grads = jax.value_and_grad(
                lambda lp: single_loss(frozen, lp, batch, channel_params)
            )(lora)
        else:
            nm = num_microbatches
            # split the *sharded* batch dim (B -> (B/nm, nm)) then swap, so
            # each device's block divides evenly into microbatches and GSPMD
            # never has to reshard (a (nm, B/nm) reshape of a batch-sharded
            # dim forces replication -> nm x redundant compute).
            mbs = jax.tree_util.tree_map(
                lambda x: jnp.swapaxes(
                    x.reshape((x.shape[0] // nm, nm) + x.shape[1:]), 0, 1),
                batch)

            def body(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(
                    lambda lp: single_loss(frozen, lp, mb, channel_params)
                )(lora)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), lora)
            (g_sum, l_sum), _ = jax.lax.scan(body, (g0, jnp.zeros(())), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / nm, g_sum)
            loss = l_sum / nm
        new_lora, new_opt = opt.update(lora, grads, opt_state)
        return new_lora, new_opt, loss

    if not per_pod_lora:
        return core

    # hierarchical schedule: one independent LoRA replica per pod
    vstep = jax.vmap(core, in_axes=(None, 0, 0, 0), out_axes=(0, 0, 0),
                     spmd_axis_name="pod")
    return vstep


def make_cloud_sync():
    """Periodic cloud-level fusion of per-pod LoRA replicas (Eq. 15 with
    uniform weights — trust weighting lives in the federation layer)."""
    def sync(lora_pods):
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x.mean(0, keepdims=True), x.shape),
            lora_pods)
    return sync


def make_serve_step(cfg: ArchConfig, *, window: int = 0, chunk: int = 4096):
    model = zoo.get_model(cfg)

    def serve_step(frozen, lora, cache, batch):
        logits, new_cache = model.decode_step(cfg, frozen, lora, cache,
                                              batch, window=window,
                                              chunk=chunk)
        nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
        return nxt.astype(jnp.int32), new_cache

    return serve_step


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def batch_pspec(mesh, global_batch: int) -> P:
    axes = data_axes(mesh)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if axes and global_batch % size == 0:
        return P(axes if len(axes) > 1 else axes[0])
    return P()


def input_shardings(cfg, mesh, shape: InputShape, specs):
    """NamedShardings for the model-input dict (batch dim data-parallel)."""
    bp = batch_pspec(mesh, shape.global_batch)
    first = tuple(bp)[0] if len(tuple(bp)) else None
    out = {}
    for k, v in specs.items():
        extra = (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, P(*((first,) + extra)))
    return out


def opt_state_shardings(opt_abstract, lora_shardings, mesh):
    """AdamW state: m/v mirror the LoRA shardings; step is replicated."""
    rep = NamedSharding(mesh, P())
    return {"step": rep,
            "m": lora_shardings,
            "v": lora_shardings}


# ---------------------------------------------------------------------------
# CLI driver: single-host LoRA fine-tuning on synthetic LM data
# ---------------------------------------------------------------------------

def _main():
    import argparse
    import time

    import numpy as np

    from repro.checkpoint import save_state
    from repro.configs import ASSIGNED, get_config

    ap = argparse.ArgumentParser(
        description="LoRA fine-tune an assigned arch on synthetic LM data")
    ap.add_argument("--arch", default="olmo-1b", choices=ASSIGNED)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (needs accelerators)")
    ap.add_argument("--elsa", action="store_true",
                    help="train through the ELSA tripartite split channel")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = zoo.get_model(cfg)
    params = None
    from repro.models.params import init_tree, count_params
    tree = init_tree(model.specs(cfg), jax.random.PRNGKey(0), cfg.dtype())
    frozen, lora = tree["frozen"], tree["lora"]
    n_frozen = count_params(model.specs(cfg)["frozen"])
    n_lora = count_params(model.specs(cfg)["lora"])
    print(f"{args.arch}{'' if args.full else ' (reduced)'}: "
          f"{n_frozen/1e6:.1f}M frozen + {n_lora/1e6:.2f}M LoRA params")

    opt = AdamW(lr=args.lr)
    opt_state = opt.init(lora)
    elsa_z = 0
    channel_params = None
    if args.elsa and cfg.family in ("dense", "moe"):
        specs, elsa_z = elsa_channel_specs(cfg)
        rngs = jax.random.split(jax.random.PRNGKey(42), 4)
        import numpy as _np
        rng = _np.random.default_rng(42)
        q_, _ = _np.linalg.qr(rng.standard_normal((16, 16)))
        channel_params = {
            "u": jnp.linalg.qr(jax.random.normal(
                rngs[0], (cfg.d_model, 16)))[0],
            "v": jnp.asarray(q_, jnp.float32),
            "bucket": jnp.asarray(rng.integers(
                0, elsa_z, (3, cfg.d_model)), jnp.int32),
            "sign": jnp.asarray(rng.choice(
                [-1.0, 1.0], (3, cfg.d_model)), jnp.float32),
        }
    step = jax.jit(make_train_step(cfg, optimizer=opt, elsa_z=elsa_z))

    # synthetic LM stream: structured bigram-ish data so loss can fall
    rng = np.random.default_rng(0)
    base = rng.integers(0, cfg.vocab_size, size=(64,))

    def sample_batch():
        starts = rng.integers(0, 64, size=(args.batch,))
        toks = np.stack([np.roll(base, -s)[: args.seq] for s in starts])
        noise = rng.integers(0, cfg.vocab_size, toks.shape)
        mask = rng.random(toks.shape) < 0.1
        return {"tokens": jnp.asarray(np.where(mask, noise, toks))}

    t0 = time.time()
    for i in range(args.steps):
        batch = sample_batch()
        if channel_params is not None:
            batch["_channel"] = channel_params
        lora, opt_state, loss = step(frozen, lora, opt_state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d}  loss {float(loss):.4f}  "
                  f"({(time.time()-t0):.1f}s)")
    if args.ckpt:
        save_state(args.ckpt, params={"lora": lora}, step=args.steps)
        print(f"saved LoRA checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    _main()
