import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
on the production meshes, with ShapeDtypeStruct stand-ins (no allocation).

For each combination this records:
  - compiled.memory_analysis()  (per-device bytes: proves it fits)
  - compiled.cost_analysis()    (XLA's aggregate flops/bytes)
  - the optimized HLO text (zstd-compressed) for the loop-aware roofline
    parser in repro.analysis.hlo_cost (XLA's cost_analysis counts while-loop
    bodies ONCE; our parser multiplies by trip counts).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import zstandard

from repro.configs import REGISTRY, ASSIGNED, get_config
from repro.configs.base import INPUT_SHAPES
from repro.launch.mesh import make_production_mesh, chips
from repro.launch.train import (make_train_step, make_serve_step,
                                batch_pspec, input_shardings,
                                opt_state_shardings)
from repro.models import zoo
from repro.models.params import abstract_tree, tree_shardings
from repro.optim import AdamW

RUNS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "runs",
                        "dryrun")


def skip_reason(arch: str, shape_name: str):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return ("full-attention architecture without a sliding-window "
                "variant; long_500k skipped per DESIGN.md §4")
    if cfg.family == "encoder" and shape.kind == "decode":
        return "encoder-only architecture has no decode step"
    return None


def build(arch: str, shape_name: str, mesh, *, per_pod_lora: bool = False,
          rules=None, chunk: int = 2048, use_flash: bool = False,
          elsa: bool = False, microbatches: int = 0, fsdp: bool = False):
    """Returns (jitted_fn, example_args) fully abstract."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    model = zoo.get_model(cfg)
    specs = model.specs(cfg)
    dt = cfg.dtype()

    frozen = abstract_tree(specs["frozen"], dt)
    lora = abstract_tree(specs["lora"], dt)
    frozen_sh = tree_shardings(specs["frozen"], mesh, rules)
    lora_sh = tree_shardings(specs["lora"], mesh, rules)

    window = cfg.sliding_window if shape_name == "long_500k" else 0
    inputs = zoo.input_specs(cfg, shape)
    if fsdp:
        # batch over BOTH axes: per-layer weight all-gather replaces
        # per-layer activation all-reduce (beyond-paper §Perf variant)
        in_sh = {k: NamedSharding(mesh, P(
            tuple(a for a in ("pod", "data", "model") if a in mesh.shape),
            *([None] * (len(v.shape) - 1))))
            for k, v in inputs.items()}
    else:
        in_sh = input_shardings(cfg, mesh, shape, inputs)

    if shape.kind == "train":
        opt = AdamW(lr=1e-4)
        # microbatch so each device sees ~2 sequences per accumulation step
        dsize = 1
        for a in ("pod", "data"):
            dsize *= mesh.shape.get(a, 1)
        per_dev = max(1, shape.global_batch // dsize)
        nm = microbatches or max(1, per_dev // 2)
        if fsdp:
            nm = 1       # fsdp shards batch over all chips: 1 seq/device
        elsa_z = 0
        if elsa:
            from repro.launch.train import elsa_channel_specs
            ch_specs, elsa_z = elsa_channel_specs(cfg)
            inputs["_channel"] = ch_specs
            in_sh["_channel"] = {k: NamedSharding(mesh, P())
                                 for k in ch_specs}
        step = make_train_step(cfg, optimizer=opt, window=window,
                               chunk=chunk, use_flash=use_flash,
                               num_microbatches=nm, elsa_z=elsa_z,
                               per_pod_lora=per_pod_lora)
        opt_abs = jax.eval_shape(opt.init, lora)
        opt_sh = opt_state_shardings(opt_abs, lora_sh, mesh)
        if per_pod_lora:
            # hierarchical ELSA schedule: independent LoRA replica per pod
            npod = mesh.shape["pod"]

            def podded(tree, sh_tree):
                t = jax.tree_util.tree_map(
                    lambda s: jax.ShapeDtypeStruct((npod,) + s.shape,
                                                   s.dtype), tree)
                sh = jax.tree_util.tree_map(
                    lambda ns: NamedSharding(
                        mesh, P(*(("pod",) + tuple(ns.spec)))), sh_tree)
                return t, sh

            lora, lora_sh = podded(lora, lora_sh)
            opt_abs, opt_sh = podded(opt_abs, opt_sh)
            inputs = {k: jax.ShapeDtypeStruct(
                (npod, v.shape[0] // npod) + v.shape[1:], v.dtype)
                for k, v in inputs.items() if k != "_channel"}
            in_sh = {k: NamedSharding(
                mesh, P("pod", "data", *([None] * (len(v.shape) - 2))))
                for k, v in inputs.items()}
        fn = jax.jit(step,
                     in_shardings=(frozen_sh, lora_sh, opt_sh, in_sh),
                     out_shardings=(lora_sh, opt_sh,
                                    NamedSharding(mesh, P("pod") if
                                                  per_pod_lora else P())))
        args = (frozen, lora, opt_abs, inputs)
    elif shape.kind == "prefill":
        def prefill(fz, lp, batch):
            logits, _ = model.forward(cfg, fz, lp, batch, window=window,
                                      chunk=chunk, remat=False)
            return jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)
        bp = batch_pspec(mesh, shape.global_batch)
        fn = jax.jit(prefill, in_shardings=(frozen_sh, lora_sh, in_sh),
                     out_shardings=NamedSharding(mesh, bp))
        args = (frozen, lora, inputs)
    else:  # decode
        cache_specs = model.cache_specs(cfg, shape.global_batch,
                                        shape.seq_len)
        cache = abstract_tree(cache_specs, dt)
        cache_sh = tree_shardings(cache_specs, mesh, rules)
        step = make_serve_step(cfg, window=window, chunk=4096)
        bp = batch_pspec(mesh, shape.global_batch)
        fn = jax.jit(step,
                     in_shardings=(frozen_sh, lora_sh, cache_sh, in_sh),
                     out_shardings=(NamedSharding(mesh, bp), cache_sh),
                     donate_argnums=(2,))
        args = (frozen, lora, cache, inputs)
    return fn, args


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            out_dir: str = RUNS_DIR, tag: str = "", save_hlo: bool = True,
            **build_kw):
    mesh_name = "pod512" if multi_pod else "pod256"
    name = f"{arch}__{shape_name}__{mesh_name}{tag}"
    reason = skip_reason(arch, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "tag": tag, "chips": 512 if multi_pod else 256}
    os.makedirs(out_dir, exist_ok=True)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        with open(os.path.join(out_dir, name + ".json"), "w") as f:
            json.dump(rec, f, indent=2)
        print(f"[dryrun] SKIP {name}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with mesh:
            fn, args = build(arch, shape_name, mesh, **build_kw)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            rec["status"] = "ok"
            rec["lower_s"] = round(t_lower, 2)
            rec["compile_s"] = round(t_compile, 2)
            if mem is not None:
                for attr in ("argument_size_in_bytes",
                             "output_size_in_bytes",
                             "temp_size_in_bytes",
                             "alias_size_in_bytes",
                             "generated_code_size_in_bytes"):
                    rec.setdefault("memory", {})[attr] = int(
                        getattr(mem, attr, 0) or 0)
                print(f"[dryrun] {name} memory_analysis:", rec["memory"])
            rec["cost"] = {k: float(v) for k, v in (cost or {}).items()
                           if isinstance(v, (int, float))}
            print(f"[dryrun] {name} cost_analysis flops="
                  f"{rec['cost'].get('flops', 0):.3e} bytes="
                  f"{rec['cost'].get('bytes accessed', 0):.3e}")
            if save_hlo:
                hlo = compiled.as_text()
                rec["hlo_bytes"] = len(hlo)
                cctx = zstandard.ZstdCompressor(level=6)
                with open(os.path.join(out_dir, name + ".hlo.zst"), "wb") as f:
                    f.write(cctx.compress(hlo.encode()))
    except Exception as e:  # noqa: BLE001 — record failures, don't die
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] FAIL {name}: {rec['error']}")
    rec["total_s"] = round(time.time() - t0, 2)
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(rec, f, indent=2)
    status = rec["status"]
    print(f"[dryrun] {name}: {status} ({rec['total_s']}s)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(REGISTRY), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out-dir", default=RUNS_DIR)
    ap.add_argument("--no-hlo", action="store_true")
    ap.add_argument("--elsa", action="store_true",
                    help="enable the ELSA split channel in train_step")
    ap.add_argument("--per-pod-lora", action="store_true",
                    help="hierarchical schedule: per-pod LoRA replicas")
    ap.add_argument("--expert-parallel", action="store_true",
                    help="shard MoE experts over the model axis")
    ap.add_argument("--chunk", type=int, default=2048)
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--fsdp", action="store_true",
                    help="shard the batch over data AND model axes")
    args = ap.parse_args()

    build_kw = {"elsa": args.elsa, "per_pod_lora": args.per_pod_lora,
                "chunk": args.chunk, "microbatches": args.microbatches,
                "fsdp": args.fsdp}
    if args.expert_parallel:
        from repro.models.params import DEFAULT_RULES
        rules = dict(DEFAULT_RULES)
        rules["experts"] = ("model",)
        rules["mlp"] = ()
        build_kw["rules"] = rules

    combos = []
    if args.all:
        for a in ASSIGNED:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    ok = fail = skip = 0
    for a, s in combos:
        rec = run_one(a, s, multi_pod=args.multi_pod, out_dir=args.out_dir,
                      tag=args.tag, save_hlo=not args.no_hlo, **build_kw)
        ok += rec["status"] == "ok"
        fail += rec["status"] == "error"
        skip += rec["status"] == "skipped"
    print(f"[dryrun] done: {ok} ok, {skip} skipped, {fail} failed")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
