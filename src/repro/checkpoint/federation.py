"""Full federation-state checkpointing (docs/robustness.md).

A federation checkpoint captures everything the round loop threads
between global rounds — global theta, the per-client channel state
(SS-OP bases), server optimizer moments, clustering outputs
(groups/divergence/trust), the live trust ledger, the numpy RNG state,
per-client batch-iterator cursors, fault-schedule cursors, the
simulated-clock/round cursor, and the recorded history/trace — so that
killing a run and resuming from its last checkpoint reproduces the
uninterrupted run *bit-identically* on the sync path (asserted by
``tests/test_checkpoint.py``; the deadline/async schedulers carry
in-flight event-queue state between rounds and do not support resume).

Writes are atomic (:func:`repro.checkpoint.checkpoint.save` renames a
temp file into place) and rolling: :class:`Checkpointer` keeps the
newest ``keep`` round snapshots and prunes the rest.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import time
from types import SimpleNamespace
from typing import Dict, List, Optional

import numpy as np

from repro import telemetry as tm
from repro.checkpoint.checkpoint import restore, save

FORMAT = "elsa-federation"
VERSION = 1

_REQUIRED = ("config", "method", "steps_per_round", "round", "t_global",
             "delta", "theta", "server_state", "groups", "div", "trust",
             "ledger", "rng_state", "draws", "dispatches", "channels",
             "history", "client_losses", "trace")
_FNAME = re.compile(r"^ckpt_round_(\d{6})\.msgpack$")


@dataclasses.dataclass
class CheckpointConfig:
    """Where/how often the round loop snapshots itself."""
    dir: str
    every: int = 1           # checkpoint every N global rounds
    keep: int = 2            # rolling window of snapshots to retain

    def __post_init__(self):
        if self.every < 1 or self.keep < 1:
            raise ValueError("CheckpointConfig.every/keep must be >= 1")


def round_path(d: str, round_idx: int) -> str:
    return os.path.join(d, f"ckpt_round_{round_idx:06d}.msgpack")


def list_checkpoints(d: str) -> List[str]:
    """Checkpoint paths in ``d``, oldest round first."""
    if not os.path.isdir(d):
        return []
    hits = [(int(m.group(1)), f) for f in os.listdir(d)
            if (m := _FNAME.match(f))]
    return [os.path.join(d, f) for _, f in sorted(hits)]


def latest_checkpoint(d: str) -> Optional[str]:
    paths = list_checkpoints(d)
    return paths[-1] if paths else None


def resolve(path_or_dir: str) -> str:
    """A concrete checkpoint file: a file path passes through, a
    directory resolves to its newest round snapshot."""
    if os.path.isdir(path_or_dir):
        latest = latest_checkpoint(path_or_dir)
        if latest is None:
            raise ValueError(
                f"no federation checkpoints in directory {path_or_dir!r}")
        return latest
    return path_or_dir


class Checkpointer:
    """Rolling atomic round snapshots."""

    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg

    def due(self, round_idx: int, last_round: int, delta: float,
            xi: float) -> bool:
        """Snapshot on the cadence, at the final round, and at the
        convergence stop (so ``resume_from`` a finished run is exact)."""
        return (round_idx % self.cfg.every == 0 or round_idx == last_round
                or delta <= xi)

    def save(self, round_idx: int, state: Dict) -> str:
        path = round_path(self.cfg.dir, round_idx)
        t0 = time.perf_counter()
        save(path, state)
        if tm.enabled():
            tm.observe("checkpoint.save_s", time.perf_counter() - t0)
            tm.inc("checkpoint.saves", 1)
            tm.inc("checkpoint.bytes_written", os.path.getsize(path))
        for old in list_checkpoints(self.cfg.dir)[:-self.cfg.keep]:
            os.unlink(old)
        return path


# ---------------------------------------------------------------------------
# state assembly / restoration
# ---------------------------------------------------------------------------

def _pairs(d: Dict) -> List:
    """int-keyed dict -> sorted [key, value] pairs (wire-stable)."""
    return [[int(k), v] for k, v in sorted(d.items())]


def _unpairs(pairs) -> Dict:
    return {int(k): v for k, v in pairs}


def build_state(fed, *, method: str, steps_per_round: int, round_idx: int,
                theta, server_state, rng, iters, history, client_losses,
                groups, div, trust, delta: float, t_global: float = 0.0,
                dispatches: Optional[Dict[int, int]] = None,
                trace_records=None, population=None) -> Dict:
    """Assemble one checkpoint payload from a live ``Federation`` run.

    ``rng`` is the loop's ``np.random.default_rng`` (its
    ``bit_generator.state`` carries 128-bit ints, which overflow
    msgpack's 64-bit integers — hence the JSON string).  ``iters`` are
    the per-client :class:`~repro.data.pipeline.CountingIterator`
    streams; only their draw counts are stored, the resumed process
    rebuilds the same seeded streams and fast-forwards.  With a bound
    ``population`` the registry carries the draw cursors instead (slots
    have no fixed occupant), so ``draws`` is stored empty and the full
    registry snapshot rides in the optional ``population`` section —
    legacy checkpoints without it keep loading unchanged.  Channels
    follow the same split: identity-keyed channels of a bound
    population live in its LRU (serialized inside the ``population``
    section), so the top-level slot-keyed ``channels`` section is empty
    there; without a population it carries ``fed._channels`` as before.
    """
    ssops = []
    for n in sorted(fed._channels):
        ch = fed._channels[n]
        ssops.append([int(n),
                      None if ch.ssop is None else
                      {"u": ch.ssop.u, "v": ch.ssop.v,
                       "w": ch.ssop.w, "w_inv": ch.ssop.w_inv}])
    hist = {k: v for k, v in history.items()
            if k not in ("final_accuracy", "client_losses", "trace",
                         "policy")}
    ledger = getattr(fed, "trust_ledger", None)
    return {
        "__format__": FORMAT, "__version__": VERSION,
        "config": dataclasses.asdict(fed.fed),
        "method": method, "steps_per_round": int(steps_per_round),
        "round": int(round_idx), "t_global": float(t_global),
        "delta": float(delta),
        "theta": theta, "server_state": server_state,
        "groups": _pairs({k: [int(n) for n in ms]
                          for k, ms in groups.items()}),
        "div": np.asarray(div), "trust": np.asarray(trust),
        "ledger": None if ledger is None else ledger.state(),
        "rng_state": json.dumps(rng.bit_generator.state),
        "draws": _pairs({} if population is not None
                        else {n: it.count for n, it in iters.items()}),
        "dispatches": _pairs(dispatches or {}),
        "channels": ssops,
        "history": hist,
        "client_losses": _pairs(client_losses),
        "trace": list(trace_records) if trace_records is not None else None,
        "population": None if population is None else population.state(),
    }


def load_state(path: str) -> Dict:
    """Read + validate a federation checkpoint; clear ``ValueError`` on
    truncation, wrong format, version skew, or missing sections."""
    t0 = time.perf_counter()
    state = restore(path)
    if tm.enabled():
        tm.observe("checkpoint.restore_s", time.perf_counter() - t0)
        tm.inc("checkpoint.restores", 1)
        tm.inc("checkpoint.bytes_read", os.path.getsize(path))
    if not isinstance(state, dict) or "__format__" not in state:
        raise ValueError(
            f"{path!r} is not a federation checkpoint (no format marker); "
            "it may be stale or written by a different tool")
    if state["__format__"] != FORMAT:
        raise ValueError(f"{path!r} has format {state['__format__']!r}, "
                         f"expected {FORMAT!r}")
    if state["__version__"] != VERSION:
        raise ValueError(
            f"{path!r} is federation-checkpoint version "
            f"{state['__version__']}, this code reads {VERSION}; "
            "re-run from scratch or upgrade in lockstep")
    missing = [k for k in _REQUIRED if k not in state]
    if missing:
        raise ValueError(f"{path!r} is missing sections {missing} — "
                         "the payload was corrupted after the header")
    return state


def restore_run(fed, state: Dict, *, method: str, steps_per_round: int,
                iters, rng, population=None) -> SimpleNamespace:
    """Rehydrate a live run from a validated checkpoint payload.

    Side effects on ``fed``: per-client channels (SS-OP bases) are
    reinstalled and the trust ledger reloaded.  ``rng`` is restored to
    the saved generator state and each client's ``iters`` stream is
    fast-forwarded to its saved draw count.  Raises ``ValueError`` when
    the checkpoint was written under a different config/method — a
    resumed run must continue the *same* experiment.

    ``population`` must match the checkpoint: a snapshot written with a
    bound :class:`~repro.population.PopulationRuntime` restores its
    registry (which carries the per-id draw cursors in place of the
    slot-keyed ``draws`` section) and refuses to resume without one,
    and vice versa.
    """
    from repro.core.split_training import Channel
    from repro.core.ssop import SSOP

    cfg_now = dataclasses.asdict(fed.fed)
    cfg_then = state["config"]
    diff = sorted(k for k in set(cfg_now) | set(cfg_then)
                  if cfg_now.get(k) != cfg_then.get(k))
    if diff:
        raise ValueError(
            f"checkpoint config mismatch on {diff}: the checkpoint was "
            f"written under a different FedConfig than this Federation")
    if state["method"] != method or \
            state["steps_per_round"] != steps_per_round:
        raise ValueError(
            f"checkpoint ran method={state['method']!r} with "
            f"steps_per_round={state['steps_per_round']}; resume asked "
            f"for method={method!r}, steps_per_round={steps_per_round}")

    pop_state = state.get("population")
    if (pop_state is not None) != (population is not None):
        raise ValueError(
            "population mismatch: the checkpoint was written "
            + ("with" if pop_state is not None else "without")
            + " a registry-backed population, this resume runs "
            + ("without" if population is None else "with") + " one")
    rng.bit_generator.state = json.loads(state["rng_state"])
    if population is not None:
        population.load_state(pop_state)
    else:
        for n, count in _unpairs(state["draws"]).items():
            iters[n].fast_forward(int(count))
    fed._channels.clear()
    for n, ss in state["channels"]:
        ssop = None if ss is None else SSOP(u=ss["u"], v=ss["v"],
                                            w=ss["w"], w_inv=ss["w_inv"])
        plan = fed.plan if fed.fed.use_channel else None
        if population is not None:
            # legacy population snapshot with slot-keyed channels: those
            # were built once at profile time, when slot n was occupied
            # by identity n, so adopting them identity-keyed is exact
            # (new snapshots carry the LRU inside the population section
            # and leave this top-level section empty)
            population.adopt_channel(int(n), Channel(ssop, plan))
        else:
            fed._channels[int(n)] = Channel(ssop, plan)
    if state["ledger"] is not None and hasattr(fed, "trust_ledger"):
        fed.trust_ledger.load_state({
            k: (np.asarray(v) if k != "beta" else v)
            for k, v in state["ledger"].items()})
    return SimpleNamespace(
        round_idx=int(state["round"]),
        t_global=float(state["t_global"]),
        delta=float(state["delta"]),
        theta=state["theta"],
        server_state=state["server_state"],
        groups=_unpairs(state["groups"]),
        div=np.asarray(state["div"]),
        trust=np.asarray(state["trust"]),
        history={k: list(v) for k, v in state["history"].items()},
        client_losses={n: list(v)
                       for n, v in _unpairs(state["client_losses"]).items()},
        dispatches={int(n): int(c)
                    for n, c in _unpairs(state["dispatches"]).items()},
        trace_records=(None if state["trace"] is None
                       else list(state["trace"])),
    )
