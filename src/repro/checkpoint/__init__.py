from repro.checkpoint.checkpoint import save, restore, save_state, restore_state  # noqa: F401
