from repro.checkpoint.checkpoint import (save, restore, save_state,  # noqa: F401
                                         restore_state, tree_equal)
from repro.checkpoint.federation import (CheckpointConfig,  # noqa: F401
                                         Checkpointer, latest_checkpoint,
                                         list_checkpoints)
