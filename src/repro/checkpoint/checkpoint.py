"""Msgpack pytree checkpointing (atomic writes, dtype/shape preserved)."""
from __future__ import annotations

import os
import tempfile
from typing import Any, Dict

import jax
import jax.numpy as jnp
import ml_dtypes  # noqa: F401 — registers bfloat16 with numpy
import msgpack
import numpy as np


def _encode_leaf(x):
    arr = np.asarray(x)
    # dtype.name keeps extended types (bfloat16 via ml_dtypes) restorable;
    # dtype.str would give opaque '|V2'
    return {b"__nd__": True,
            b"dtype": arr.dtype.name.encode(),
            b"shape": list(arr.shape),
            b"data": arr.tobytes()}


def _is_encoded(obj):
    return isinstance(obj, dict) and obj.get(b"__nd__", False)


def _decode_leaf(obj):
    arr = np.frombuffer(obj[b"data"], dtype=np.dtype(obj[b"dtype"].decode()))
    return jnp.asarray(arr.reshape(obj[b"shape"]))


def _to_wire(tree):
    if isinstance(tree, dict):
        return {k: _to_wire(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_to_wire(v) for v in tree]
    return _encode_leaf(tree)


def _from_wire(obj):
    if _is_encoded(obj):
        return _decode_leaf(obj)
    if isinstance(obj, dict):
        return {(k.decode() if isinstance(k, bytes) else k): _from_wire(v)
                for k, v in obj.items()}
    if isinstance(obj, list):
        return [_from_wire(v) for v in obj]
    return obj


def save(path: str, tree: Any) -> None:
    payload = msgpack.packb(_to_wire(tree), use_bin_type=True)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d)
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(payload)
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def restore(path: str) -> Any:
    with open(path, "rb") as f:
        return _from_wire(msgpack.unpackb(f.read(), raw=True))


def save_state(path: str, *, params=None, opt_state=None,
               step: int = 0, extra: Dict = None) -> None:
    save(path, {"params": params, "opt_state": opt_state,
                "step": np.asarray(step), "extra": extra or {}})


def restore_state(path: str):
    return restore(path)
