"""Msgpack pytree checkpointing (atomic writes, dtype/shape preserved).

Wire format (version 2):

- array leaves (numpy / jax arrays and numpy scalars) are encoded as
  ``{"__nd__": True, dtype, shape, data}`` with ``dtype.name`` so
  extended types (bfloat16 via ml_dtypes) restore exactly;
- python primitives (``None``/``bool``/``int``/``float``/``str``) pass
  through msgpack natively — a float leaf comes back as a float, not a
  0-d array, so run histories and metadata round-trip by value;
- **tuples are preserved**: a tuple node is wrapped as
  ``{"__tuple__": [items]}`` so ``restore`` returns the *same pytree
  treedef* that was saved (a list-vs-tuple mismatch silently breaks
  ``tree_map`` against live optimizer/parameter trees).

``save_state``/``restore_state`` add a format marker + version and
validate the payload on load: a truncated file, a stale pre-versioned
checkpoint, or a payload missing its required sections fails with a
clear ``ValueError`` instead of a downstream shape/KeyError.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any, Dict

import jax
import ml_dtypes  # noqa: F401 — registers bfloat16 with numpy
import msgpack
import numpy as np

#: Format marker + version written by :func:`save_state`.
STATE_FORMAT = "repro-state"
STATE_VERSION = 2

_ND = "__nd__"
_TUPLE = "__tuple__"
_PRIMITIVES = (bool, int, float, str)


def _encode_leaf(x):
    arr = np.asarray(x)
    if arr.dtype == object:
        raise TypeError(f"cannot checkpoint object-dtype leaf {x!r}")
    # dtype.name keeps extended types (bfloat16 via ml_dtypes) restorable;
    # dtype.str would give opaque '|V2'
    return {_ND: True,
            "dtype": arr.dtype.name,
            "shape": list(arr.shape),
            "data": arr.tobytes()}


def _is_encoded(obj):
    return isinstance(obj, dict) and obj.get(_ND, False)


def _decode_leaf(obj):
    # decode to numpy, NOT jnp: jnp.asarray would silently downcast
    # float64/int64 leaves under jax's default x64-disabled config,
    # breaking bit-exact restoration (trust/divergence stats are f64).
    # jax ops convert numpy operands on use, so callers never notice.
    arr = np.frombuffer(obj["data"], dtype=np.dtype(obj["dtype"]))
    return arr.reshape(obj["shape"]).copy()


def _to_wire(tree):
    if isinstance(tree, dict):
        return {k: _to_wire(v) for k, v in tree.items()}
    if isinstance(tree, tuple):
        return {_TUPLE: [_to_wire(v) for v in tree]}
    if isinstance(tree, list):
        return [_to_wire(v) for v in tree]
    if tree is None or isinstance(tree, _PRIMITIVES):
        return tree
    return _encode_leaf(tree)


def _from_wire(obj):
    if isinstance(obj, dict):
        if _is_encoded(obj):
            return _decode_leaf(obj)
        if _TUPLE in obj and len(obj) == 1:
            return tuple(_from_wire(v) for v in obj[_TUPLE])
        return {(k.decode() if isinstance(k, bytes) else k): _from_wire(v)
                for k, v in obj.items()}
    if isinstance(obj, list):
        return [_from_wire(v) for v in obj]
    return obj


def save(path: str, tree: Any) -> None:
    """Atomically write ``tree`` to ``path`` (write-temp + rename, so a
    crash mid-write never leaves a truncated checkpoint in place)."""
    payload = msgpack.packb(_to_wire(tree), use_bin_type=True)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d)
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(payload)
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def restore(path: str) -> Any:
    """Load a pytree written by :func:`save`.  Raises ``ValueError`` with
    a clear message when the file is truncated or not a checkpoint."""
    with open(path, "rb") as f:
        raw = f.read()
    try:
        wire = msgpack.unpackb(raw, raw=False, strict_map_key=False)
    except Exception as e:                      # truncated / not msgpack
        raise ValueError(
            f"checkpoint {path!r} is corrupt or truncated "
            f"({len(raw)} bytes): {e}") from e
    return _from_wire(wire)


def save_state(path: str, *, params=None, opt_state=None,
               step: int = 0, extra: Dict = None) -> None:
    save(path, {"__format__": STATE_FORMAT,
                "__version__": STATE_VERSION,
                "params": params, "opt_state": opt_state,
                "step": int(step), "extra": extra or {}})


def restore_state(path: str):
    """Load + validate a :func:`save_state` checkpoint.

    Raises ``ValueError`` when the file is truncated, predates the
    format-version field (stale), comes from an incompatible version,
    or is missing a required section — so a bad checkpoint fails here
    with an actionable message rather than as a downstream
    shape/KeyError.
    """
    state = restore(path)
    if not isinstance(state, dict) or "__format__" not in state:
        raise ValueError(
            f"checkpoint {path!r} has no format marker — it is either "
            "stale (written before format versioning) or not a "
            "save_state checkpoint; re-save it with the current code")
    if state["__format__"] != STATE_FORMAT:
        raise ValueError(
            f"checkpoint {path!r} has format {state['__format__']!r}, "
            f"expected {STATE_FORMAT!r}")
    if state["__version__"] != STATE_VERSION:
        raise ValueError(
            f"checkpoint {path!r} has format version "
            f"{state['__version__']}, this code reads version "
            f"{STATE_VERSION}; re-save it with the matching code")
    missing = [k for k in ("params", "opt_state", "step", "extra")
               if k not in state]
    if missing:
        raise ValueError(
            f"checkpoint {path!r} is missing sections {missing} — "
            "the payload was corrupted after the header")
    return state


def tree_equal(a, b) -> bool:
    """Exact equality of two pytrees: same treedef (tuple-vs-list and
    dict keys included), same leaf dtypes/shapes/bits."""
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    if ta != tb or len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        xa, ya = np.asarray(x), np.asarray(y)
        if xa.dtype != ya.dtype or xa.shape != ya.shape:
            return False
        if xa.tobytes() != ya.tobytes():
            return False
    return True
