"""Pure-jnp oracle for the SS-OP kernel."""
from __future__ import annotations

import jax.numpy as jnp


def ssop_apply_ref(h, u, w):
    """out = H + (H U) W Uᵀ, fp32 accumulation."""
    hf = h.astype(jnp.float32)
    uf = u.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    return (hf + (hf @ uf) @ wf @ uf.T).astype(h.dtype)
