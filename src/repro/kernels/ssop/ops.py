"""jit'd wrapper for the SS-OP kernel: forward rotation and inverse."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssop.kernel import ssop_apply_td


def ssop_apply(h, u, v, *, interpret: bool = True):
    """H -> H Qᵀ = H + (HU)(Vᵀ - I)Uᵀ.  h: (..., D)."""
    r = v.shape[0]
    w = v.T - jnp.eye(r, dtype=v.dtype)
    lead = h.shape[:-1]
    flat = h.reshape(-1, h.shape[-1])
    out = ssop_apply_td(flat, u.astype(h.dtype), w.astype(h.dtype),
                        interpret=interpret)
    return out.reshape(lead + (h.shape[-1],))


def ssop_apply_inverse(h, u, v, *, interpret: bool = True):
    """H -> H Q = H + (HU)(V - I)Uᵀ (exact inverse, Q orthogonal)."""
    r = v.shape[0]
    w = v - jnp.eye(r, dtype=v.dtype)
    lead = h.shape[:-1]
    flat = h.reshape(-1, h.shape[-1])
    out = ssop_apply_td(flat, u.astype(h.dtype), w.astype(h.dtype),
                        interpret=interpret)
    return out.reshape(lead + (h.shape[-1],))
