"""jit'd wrapper for the SS-OP kernel: forward rotation and inverse.

``interpret=None`` resolves backend-aware (compiled Mosaic on TPU, the
Pallas interpreter elsewhere); override process-wide with
``repro.kernels.set_interpret``.  When the caller already carries the
fused update matrix ``w`` (``SSOP.w`` / ``SSOP.w_inv``, precomputed once
per channel by ``make_ssop``) pass it directly to skip the per-call
identity subtraction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssop.kernel import ssop_apply_td


def ssop_apply(h, u, v, *, w=None, interpret=None):
    """H -> H Qᵀ = H + (HU)(Vᵀ - I)Uᵀ.  h: (..., D)."""
    if w is None:
        r = v.shape[0]
        w = v.T - jnp.eye(r, dtype=v.dtype)
    lead = h.shape[:-1]
    flat = h.reshape(-1, h.shape[-1])
    out = ssop_apply_td(flat, u.astype(h.dtype), w.astype(h.dtype),
                        interpret=interpret)
    return out.reshape(lead + (h.shape[-1],))


def ssop_apply_inverse(h, u, v, *, w=None, interpret=None):
    """H -> H Q = H + (HU)(V - I)Uᵀ (exact inverse, Q orthogonal)."""
    if w is None:
        r = v.shape[0]
        w = v - jnp.eye(r, dtype=v.dtype)
    lead = h.shape[:-1]
    flat = h.reshape(-1, h.shape[-1])
    out = ssop_apply_td(flat, u.astype(h.dtype), w.astype(h.dtype),
                        interpret=interpret)
    return out.reshape(lead + (h.shape[-1],))
