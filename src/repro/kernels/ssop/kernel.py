"""SS-OP fused low-rank rotation Pallas TPU kernel (Eq. 19).

Computes ``out = H + (H U) W Uᵀ`` with ``W = Vᵀ - I`` (r×r, precomputed)
without ever materializing the D×D Q matrix.  U (D, r) and W stay resident
in VMEM; rows of H stream through in (bt, D) tiles.  VMEM: bt·D + D·r +
r² fp32 — bt=128, D=8192, r=16 → ~4.6 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret


def _ssop_kernel(h_ref, u_ref, w_ref, o_ref):
    h = h_ref[...].astype(jnp.float32)            # (bt, D)
    u = u_ref[...].astype(jnp.float32)            # (D, r)
    w = w_ref[...].astype(jnp.float32)            # (r, r)
    p = jax.lax.dot(h, u, preferred_element_type=jnp.float32)      # (bt, r)
    pw = jax.lax.dot(p, w, preferred_element_type=jnp.float32)     # (bt, r)
    upd = jax.lax.dot_general(pw, u, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (bt, D)
    o_ref[...] = (h + upd).astype(o_ref.dtype)


def ssop_apply_td(h, u, w, *, bt: int = 128, interpret: bool | None = None):
    """h: (T, D); u: (D, r); w: (r, r) = Vᵀ - I  ->  H + (HU)WUᵀ.

    ``interpret=None`` -> backend-aware default (compiled on TPU,
    interpreter elsewhere; :func:`repro.kernels.resolve_interpret`).
    """
    interpret = resolve_interpret(interpret)
    T, D = h.shape
    bt = min(bt, T)
    assert T % bt == 0
    return pl.pallas_call(
        _ssop_kernel,
        grid=(T // bt,),
        in_specs=[
            pl.BlockSpec((bt, D), lambda t: (t, 0)),
            pl.BlockSpec(u.shape, lambda t: (0, 0)),
            pl.BlockSpec(w.shape, lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, D), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), h.dtype),
        interpret=interpret,
    )(h, u, w)
