"""Fused LoRA matmul Pallas TPU kernel: y = x W + (x A) B · s.

Grid: (nt, no, nk) with the contraction (k) innermost; two fp32 VMEM
accumulators — the main (bt, bo) tile and the low-rank (bt, r) projection —
advance together, so the xA intermediate never round-trips through HBM.
B (r, bo-tile) is applied on the final k step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import resolve_interpret


def _lora_kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, accp_ref, *,
                 nk: int, scale: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        accp_ref[...] = jnp.zeros_like(accp_ref)

    x = x_ref[...].astype(jnp.float32)            # (bt, bk)
    w = w_ref[...].astype(jnp.float32)            # (bk, bo)
    a = a_ref[...].astype(jnp.float32)            # (bk, r)
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)
    accp_ref[...] += jax.lax.dot(x, a, preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        b = b_ref[...].astype(jnp.float32)        # (r, bo)
        y = acc_ref[...] + scale * jax.lax.dot(
            accp_ref[...], b, preferred_element_type=jnp.float32)
        o_ref[...] = y.astype(o_ref.dtype)


def lora_matmul_td(x, w, a, b, scale: float, *, bt: int = 256,
                   bo: int = 512, bk: int = 512,
                   interpret: bool | None = None):
    """x: (T, K); w: (K, O); a: (K, r); b: (r, O) -> (T, O).

    ``interpret=None`` -> backend-aware default (compiled on TPU).
    """
    interpret = resolve_interpret(interpret)
    T, K = x.shape
    _, O = w.shape
    r = a.shape[1]
    bt, bo, bk = min(bt, T), min(bo, O), min(bk, K)
    assert T % bt == 0 and O % bo == 0 and K % bk == 0
    nt, no, nk = T // bt, O // bo, K // bk
    kernel = functools.partial(_lora_kernel, nk=nk, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(nt, no, nk),
        in_specs=[
            pl.BlockSpec((bt, bk), lambda t, o, k: (t, k)),
            pl.BlockSpec((bk, bo), lambda t, o, k: (k, o)),
            pl.BlockSpec((bk, r), lambda t, o, k: (k, 0)),
            pl.BlockSpec((r, bo), lambda t, o, k: (0, o)),
        ],
        out_specs=pl.BlockSpec((bt, bo), lambda t, o, k: (t, o)),
        out_shape=jax.ShapeDtypeStruct((T, O), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, bo), jnp.float32),
                        pltpu.VMEM((bt, r), jnp.float32)],
        interpret=interpret,
    )(x, w, a, b)
