"""jit'd wrapper for the fused LoRA matmul.

``interpret=None`` resolves backend-aware (compiled Mosaic on TPU, the
Pallas interpreter elsewhere); see ``repro.kernels.set_interpret``.
"""
from __future__ import annotations

import jax

from repro.kernels.lora.kernel import lora_matmul_td


def lora_matmul(x, w, a, b, scale: float, *, interpret: bool | None = None):
    """x: (..., K) -> (..., O): x W + s (x A) B fused."""
    lead = x.shape[:-1]
    flat = x.reshape(-1, x.shape[-1])
    out = lora_matmul_td(flat, w, a, b, scale, interpret=interpret)
    return out.reshape(lead + (w.shape[-1],))
