"""jit'd wrapper for the fused LoRA matmul."""
from __future__ import annotations

import jax

from repro.kernels.lora.kernel import lora_matmul_td


def lora_matmul(x, w, a, b, scale: float, *, interpret: bool = True):
    """x: (..., K) -> (..., O): x W + s (x A) B fused."""
    lead = x.shape[:-1]
    flat = x.reshape(-1, x.shape[-1])
    out = lora_matmul_td(flat, w, a, b, scale, interpret=interpret)
    return out.reshape(lead + (w.shape[-1],))
