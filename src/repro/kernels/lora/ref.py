"""Pure-jnp oracle for the fused LoRA matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def lora_matmul_ref(x, w, a, b, scale: float):
    xf = x.astype(jnp.float32)
    y = xf @ w.astype(jnp.float32) \
        + scale * (xf @ a.astype(jnp.float32)) @ b.astype(jnp.float32)
    return y.astype(x.dtype)
