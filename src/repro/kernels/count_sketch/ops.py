"""jit'd wrappers binding SketchPlans to the count-sketch kernels.

The signed-selection tensor comes from ``selection_matrices(plan)``,
which returns the copy cached on the plan by ``make_plan`` (no per-call
one-hot rebuild).  ``interpret=None`` resolves backend-aware; see
``repro.kernels.set_interpret``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sketch import SketchPlan, selection_matrices
from repro.kernels.count_sketch.kernel import (sketch_compress_tz,
                                               sketch_decompress_tz)


def _flatten(h):
    lead = h.shape[:-1]
    return h.reshape(-1, h.shape[-1]), lead


def sketch_compress(h, plan: SketchPlan, *, interpret: bool | None = None):
    """h: (..., D) -> (..., Y, Z) via the Pallas MXU kernel."""
    s = selection_matrices(plan)
    flat, lead = _flatten(h)
    out = sketch_compress_tz(flat, s, interpret=interpret)
    return out.reshape(lead + (plan.y, plan.z))


def sketch_decompress(u, plan: SketchPlan, *, interpret: bool | None = None):
    """u: (..., Y, Z) -> (..., D)."""
    s = selection_matrices(plan)
    lead = u.shape[:-2]
    flat = u.reshape(-1, plan.y, plan.z)
    out = sketch_decompress_tz(flat, s, interpret=interpret)
    return out.reshape(lead + (plan.d,))
