"""Count-sketch compress/decompress Pallas TPU kernels (Eqs. 20–21).

TPU adaptation (DESIGN.md §3): the hash scatter/gather is re-expressed as
matmuls against a dense signed-selection tensor S (Y, D, Z), S[y,d,z] =
sign[y,d]·1[bucket[y,d]=z], so both directions run on the MXU:

  compress:   out[t,y,:]  = Σ_d H[t,d]·S[y,d,:]      (T,D)x(D,Z) per y
  decompress: est[t,y,d]  = Σ_z U[t,y,z]·S[y,d,z]    (T,Z)x(Z,D) per y
              out[t,d]    = median_y est[t,y,d]       (compare-exchange net)

Blocks are (bt, bd) tiles with fp32 accumulation in VMEM scratch; the
(y, z) extent is small (Y≈3, Z≈D/(ρY)) and stays resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import resolve_interpret


def _compress_kernel(h_ref, s_ref, o_ref, acc_ref, *, nd: int):
    di = pl.program_id(2)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = h_ref[...].astype(jnp.float32)            # (bt, bd)
    s = s_ref[0].astype(jnp.float32)              # (bd, Z)
    acc_ref[...] += jax.lax.dot(h, s, preferred_element_type=jnp.float32)

    @pl.when(di == nd - 1)
    def _finish():
        o_ref[:, 0, :] = acc_ref[...].astype(o_ref.dtype)


def sketch_compress_tz(h, s, *, bt: int = 256, bd: int = 512,
                       interpret: bool | None = None):
    """h: (T, D); s: (Y, D, Z) -> (T, Y, Z).

    ``interpret=None`` -> backend-aware default (compiled on TPU).
    """
    interpret = resolve_interpret(interpret)
    T, D = h.shape
    Y, _, Z = s.shape
    bt = min(bt, T)
    bd = min(bd, D)
    assert T % bt == 0 and D % bd == 0
    nt, nd = T // bt, D // bd
    kernel = functools.partial(_compress_kernel, nd=nd)
    return pl.pallas_call(
        kernel,
        grid=(nt, Y, nd),
        in_specs=[
            pl.BlockSpec((bt, bd), lambda t, y, d: (t, d)),
            pl.BlockSpec((1, bd, Z), lambda t, y, d: (y, d, 0)),
        ],
        out_specs=pl.BlockSpec((bt, 1, Z), lambda t, y, d: (t, y, 0)),
        out_shape=jax.ShapeDtypeStruct((T, Y, Z), h.dtype),
        scratch_shapes=[pltpu.VMEM((bt, Z), jnp.float32)],
        interpret=interpret,
    )(h, s)


def _median_rows(rows):
    n = len(rows)
    rows = list(rows)
    for i in range(n):
        for j in range(n - 1 - i):
            lo = jnp.minimum(rows[j], rows[j + 1])
            hi = jnp.maximum(rows[j], rows[j + 1])
            rows[j], rows[j + 1] = lo, hi
    if n % 2:
        return rows[(n - 1) // 2]
    return 0.5 * (rows[n // 2 - 1] + rows[n // 2])


def _decompress_kernel(u_ref, s_ref, o_ref, *, y: int):
    u = u_ref[...].astype(jnp.float32)            # (bt, Y, Z)
    ests = []
    for yy in range(y):
        s_y = s_ref[...][yy].astype(jnp.float32)  # (bd, Z)
        ests.append(jax.lax.dot_general(
            u[:, yy, :], s_y, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32))  # (bt, bd)
    o_ref[...] = _median_rows(ests).astype(o_ref.dtype)


def sketch_decompress_tz(u, s, *, bt: int = 256, bd: int = 512,
                         interpret: bool | None = None):
    """u: (T, Y, Z); s: (Y, D, Z) -> (T, D) median estimates.

    ``interpret=None`` -> backend-aware default (compiled on TPU).
    """
    interpret = resolve_interpret(interpret)
    T, Y, Z = u.shape
    _, D, _ = s.shape
    bt = min(bt, T)
    bd = min(bd, D)
    assert T % bt == 0 and D % bd == 0
    kernel = functools.partial(_decompress_kernel, y=Y)
    return pl.pallas_call(
        kernel,
        grid=(T // bt, D // bd),
        in_specs=[
            pl.BlockSpec((bt, Y, Z), lambda t, d: (t, 0, 0)),
            pl.BlockSpec((Y, bd, Z), lambda t, d: (0, d, 0)),
        ],
        out_specs=pl.BlockSpec((bt, bd), lambda t, d: (t, d)),
        out_shape=jax.ShapeDtypeStruct((T, D), u.dtype),
        interpret=interpret,
    )(u, s)
