"""Pure-jnp oracle for the count-sketch kernels."""
from __future__ import annotations

import jax.numpy as jnp


def compress_ref(h, s):
    """h: (T, D); s: (Y, D, Z) -> (T, Y, Z)."""
    return jnp.einsum("td,ydz->tyz", h.astype(jnp.float32),
                      s.astype(jnp.float32)).astype(h.dtype)


def decompress_ref(u, s):
    """u: (T, Y, Z); s: (Y, D, Z) -> (T, D) median-of-Y estimates."""
    est = jnp.einsum("tyz,ydz->tyd", u.astype(jnp.float32),
                     s.astype(jnp.float32))
    # median over Y via sort-free compare-exchange (matches kernel exactly)
    rows = [est[:, i, :] for i in range(est.shape[1])]
    n = len(rows)
    for i in range(n):
        for j in range(n - 1 - i):
            lo = jnp.minimum(rows[j], rows[j + 1])
            hi = jnp.maximum(rows[j], rows[j + 1])
            rows[j], rows[j + 1] = lo, hi
    med = rows[(n - 1) // 2] if n % 2 else 0.5 * (rows[n // 2 - 1]
                                                  + rows[n // 2])
    return med.astype(u.dtype)
