# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Pallas kernel families (flash_attention, ssop, count_sketch, lora).

All ``pallas_call`` entry points take ``interpret=None`` and resolve it
through :func:`resolve_interpret`: compiled Mosaic on TPU, the Pallas
interpreter everywhere else (CPU/GPU test runs).  ``set_interpret``
overrides the default process-wide — e.g. ``set_interpret(True)`` to
force interpreter semantics on TPU while debugging, or
``set_interpret(False)`` on a backend with native Pallas lowering.
"""
from __future__ import annotations

from typing import Optional

_INTERPRET_OVERRIDE: Optional[bool] = None


def set_interpret(value: Optional[bool]) -> None:
    """Force the ``interpret`` default for every kernel family.

    ``True``/``False`` pins the mode; ``None`` restores the backend-aware
    default (``interpret = jax.default_backend() != "tpu"``).
    """
    global _INTERPRET_OVERRIDE
    _INTERPRET_OVERRIDE = value


def resolve_interpret(value: Optional[bool] = None) -> bool:
    """Resolve a per-call ``interpret`` argument to a concrete bool."""
    if value is not None:
        return value
    if _INTERPRET_OVERRIDE is not None:
        return _INTERPRET_OVERRIDE
    import jax
    return jax.default_backend() != "tpu"
