"""Blocked online-softmax (flash) attention Pallas TPU kernel.

Grid: (B*H, num_q_blocks, num_kv_blocks) — kv innermost so the fp32
running (m, l, acc) VMEM scratch persists across kv steps.  GQA is handled
in the k/v BlockSpec index maps (q head h reads kv head h // G).  Causal
and sliding-window masking via 2D broadcasted iota.

VMEM budget per step: q (bq, d) + k,v (bk, d) + acc (bq, d) fp32 —
with bq=bk=512, d=128 that is ~0.9 MB, comfortably inside a v5e core's
~16 MB VMEM; matmul dims are kept multiples of 128 for MXU alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import resolve_interpret

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: int,
                 bq: int, bk: int, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                           # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0, :, :] = (acc_ref[...] /
                          jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         scale=None, bq: int = 512, bk: int = 512,
                         interpret: bool | None = None):
    """q: (BH, Sq, D); k, v: (BKV, Sk, D) with BH = BKV * G.

    ``interpret=None`` resolves backend-aware (compiled on TPU,
    interpreter elsewhere); see :func:`repro.kernels.resolve_interpret`.
    """
    interpret = resolve_interpret(interpret)
    BH, Sq, D = q.shape
    BKV, Sk, _ = k.shape
    G = BH // BKV
    scale = D ** -0.5 if scale is None else scale
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    n_q, n_kv = Sq // bq, Sk // bk

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv=n_kv)

    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki, g=G: (bh // g, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki, g=G: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
