"""jit'd public wrapper for the flash attention kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    kv_valid=None, bq=512, bk=512, interpret=None):
    """q: (B, Sq, H, Dh); k, v: (B, Sk, KV, Dh) -> (B, Sq, H, Dh).

    Training/prefill path (q_offset=0, full cache valid); decode uses the
    jnp online-softmax path in :mod:`repro.models.common`.
    ``interpret=None`` -> backend-aware default (compiled on TPU).
    Resolved *before* the jit boundary so ``set_interpret`` changes take
    effect on the next call instead of being frozen into the jit cache.
    """
    from repro.kernels import resolve_interpret
    return _flash_attention(q, k, v, causal=causal, window=window,
                            q_offset=q_offset, kv_valid=kv_valid, bq=bq,
                            bk=bk, interpret=resolve_interpret(interpret))


@partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                   "kv_valid", "bq", "bk", "interpret"))
def _flash_attention(q, k, v, *, causal, window, q_offset, kv_valid,
                     bq, bk, interpret):
    assert q_offset == 0 and kv_valid is None, \
        "flash kernel covers the train/prefill path"
    B, Sq, H, Dh = q.shape
    _, Sk, KV, _ = k.shape
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, Dh)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, Dh)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, Dh)
    o = flash_attention_bhsd(qr, kr, vr, causal=causal, window=window,
                             bq=bq, bk=bk, interpret=interpret)
    return o.reshape(B, H, Sq, Dh).transpose(0, 2, 1, 3)
