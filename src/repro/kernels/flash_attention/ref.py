"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_bhsd_ref(q, k, v, *, causal: bool = True, window: int = 0,
                       scale=None):
    """q: (BH, Sq, D); k, v: (BKV, Sk, D); direct masked softmax."""
    BH, Sq, D = q.shape
    BKV, Sk, _ = k.shape
    G = BH // BKV
    scale = D ** -0.5 if scale is None else scale
    kk = jnp.repeat(k, G, axis=0)
    vv = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vv.astype(jnp.float32)
                      ).astype(q.dtype)
