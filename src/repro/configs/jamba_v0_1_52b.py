"""Jamba-v0.1-52B [arXiv:2403.19887] — Mamba + attention 1:7 hybrid with MoE.

32 layers, d_model=4096, 32 heads (GQA kv=8) on the attention layers,
d_ff=14336, vocab=65536; MoE (16 experts top-2) on every other layer.
Attention appears once per 8 layers (1:7 interleave).  Mamba layers give
O(1)-state decode => long_500k runs.
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    attn_every=8,
    moe=MoEConfig(num_experts=16, experts_per_token=2, expert_d_ff=14336,
                  every=2, capacity_factor=1.25),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=128),
    supports_long_context=True,
    source="arXiv:2403.19887 (Jamba)",
)
