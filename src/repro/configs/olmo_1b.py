"""OLMo-1B [arXiv:2402.00838] — dense decoder with non-parametric LayerNorm.

16 layers, d_model=2048, 16 heads (MHA: kv=16), d_ff=8192, vocab=50304.
long_500k via sliding-window variant.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparametric",
    tie_embeddings=True,
    sliding_window=8192,
    supports_long_context=True,
    source="arXiv:2402.00838 (OLMo), 1B configuration",
)
