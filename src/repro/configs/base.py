"""Architecture + run configuration for the ELSA reproduction framework.

Every assigned architecture gets a module in this package exporting CONFIG,
an :class:`ArchConfig`.  The registry in ``__init__`` maps the public
``--arch`` ids (which contain dots/dashes) onto those modules.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Input shapes (assigned).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture configuration.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    num_shared_experts: int = 0
    expert_d_ff: int = 0          # d_ff per expert
    every: int = 1                # MoE layer period (1 = every block)
    first_dense_layers: int = 0   # leading dense blocks (deepseek-v2)
    dense_d_ff: int = 0           # d_ff of the dense blocks when first_dense>0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    # xLSTM
    slstm_every: int = 8          # one sLSTM block per this many blocks
    proj_factor: float = 2.0      # mLSTM up-projection factor
    conv_kernel: int = 4
    # mamba (jamba)
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model/16)
    chunk: int = 128              # chunkwise-parallel scan chunk


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 16
    alpha: float = 32.0
    # projection names that receive adapters
    targets: Tuple[str, ...] = ("q", "k", "v", "o")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio | encoder
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0             # 0 -> d_model // num_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"         # rmsnorm | layernorm | nonparametric
    act: str = "silu"             # silu | gelu
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    max_position_embeddings: int = 0   # 0 -> rotary (no table); >0 -> learned table

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    lora: LoRAConfig = dataclasses.field(default_factory=LoRAConfig)

    # hybrid / vlm / audio structure
    attn_every: int = 1           # jamba: attention layer period (others: 1)
    cross_attn_every: int = 0     # vlm: cross-attn layer period (0 = none)
    encoder_layers: int = 0       # audio enc-dec
    num_vision_tokens: int = 1024 # stubbed frontend output length (vlm)
    num_audio_frames: int = 1500  # stubbed frontend output length (audio)

    sliding_window: int = 0       # 0 = full attention; >0 enables windowed attn
    supports_long_context: bool = False  # may run long_500k

    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"

    # citation for the config values
    source: str = ""

    # ---------------- derived -------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards on a 16-way axis."""
        return ((self.vocab_size + 255) // 256) * 256

    def dtype(self) -> jnp.dtype:
        return jnp.dtype(self.param_dtype)

    def adtype(self) -> jnp.dtype:
        return jnp.dtype(self.activation_dtype)

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---------------- reduced variant for CPU smoke tests ---------------------
    def reduced(self) -> "ArchConfig":
        """Same family, tiny dimensions: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads))
        # keep the GQA ratio representative
        if self.num_kv_heads < self.num_heads:
            kv = max(1, heads // min(self.q_per_kv, heads))
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                experts_per_token=min(self.moe.experts_per_token, 2),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                expert_d_ff=min(self.moe.expert_d_ff, 128) if self.moe.expert_d_ff else 0,
                every=min(self.moe.every, 2),
                first_dense_layers=min(self.moe.first_dense_layers, 1),
                dense_d_ff=min(self.moe.dense_d_ff, 256) if self.moe.dense_d_ff else 0,
            )
        mla = None
        if self.mla is not None:
            mla = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                            rope_head_dim=16, nope_head_dim=32, v_head_dim=32)
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, d_state=8, chunk=16)
        # period-structured families keep 2 (reduced) periods
        attn_every = min(self.attn_every, 2) if self.family == "hybrid" else 1
        cross_every = 2 if self.cross_attn_every else 0
        if ssm is not None and self.family == "ssm":
            ssm = dataclasses.replace(ssm, slstm_every=2)
        if self.family == "hybrid":
            n_layers = 2 * attn_every
        elif self.family == "vlm":
            n_layers = 2 * cross_every
        elif self.family == "ssm":
            n_layers = 2 * ssm.slstm_every
        else:
            n_layers = 2
        return dataclasses.replace(
            self,
            num_layers=n_layers,
            attn_every=attn_every,
            cross_attn_every=cross_every,
            encoder_layers=min(self.encoder_layers, 2),
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=0 if self.head_dim == 0 else min(self.head_dim, d_model // heads),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 1024),
            moe=moe, mla=mla, ssm=ssm,
            lora=dataclasses.replace(self.lora, rank=4, alpha=8.0),
            num_vision_tokens=min(self.num_vision_tokens, 16),
            num_audio_frames=min(self.num_audio_frames, 24),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            param_dtype="float32",
            activation_dtype="float32",
            max_position_embeddings=(min(self.max_position_embeddings, 512)
                                     if self.max_position_embeddings else 0),
        )

    def layer_kinds(self) -> list:
        """Per-layer block kinds, e.g. ['mamba','attn',...] for hybrids."""
        kinds = []
        for i in range(self.num_layers):
            if self.family == "ssm":
                kinds.append("slstm" if (self.ssm and self.ssm.slstm_every
                                         and i % self.ssm.slstm_every == self.ssm.slstm_every - 1)
                             else "mlstm")
            elif self.family == "hybrid":
                kinds.append("attn" if i % self.attn_every == self.attn_every // 2
                             else "mamba")
            elif self.family == "vlm":
                kinds.append("cross" if (self.cross_attn_every and
                                         i % self.cross_attn_every == self.cross_attn_every - 1)
                             else "attn")
            else:
                kinds.append("attn")
        return kinds
