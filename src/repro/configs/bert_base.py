"""BERT-base-uncased — the paper's own LLM (§IV.A: 12 blocks, hidden 768,
12 heads, ~110M params).  Encoder-only: no decode shapes.
"""
from repro.configs.base import ArchConfig, LoRAConfig

CONFIG = ArchConfig(
    name="bert-base",
    family="encoder",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=30522,
    norm="layernorm",
    act="gelu",
    max_position_embeddings=512,
    lora=LoRAConfig(rank=8, alpha=16.0, targets=("q", "v")),
    supports_long_context=False,
    source="ELSA paper §IV.A (BERT-base-uncased)",
)
