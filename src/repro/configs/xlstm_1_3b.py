"""xLSTM-1.3B [arXiv:2405.04517] — sLSTM + mLSTM blocks, attention-free.

48 blocks, d_model=2048, 4 heads (head_dim 512), no separate FFN (d_ff=0):
the mLSTM block carries its own 2x up-projection.  xLSTM[7:1] ratio — one
sLSTM block per 8.
"""
from repro.configs.base import ArchConfig, SSMConfig, LoRAConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=512,
    norm="layernorm",
    act="gelu",
    ssm=SSMConfig(slstm_every=8, proj_factor=2.0, conv_kernel=4, chunk=128),
    lora=LoRAConfig(rank=16, alpha=32.0, targets=("q", "k", "v")),
    supports_long_context=True,   # recurrent state: O(1) per decoded token
    source="arXiv:2405.04517 (xLSTM), 1.3B configuration",
)
