"""Llama-3.2-Vision-90B [hf:meta-llama/Llama-3.2-11B-Vision, scaled per
assignment] — decoder with cross-attention image layers every 5th block.

100 layers, d_model=8192, 64 heads (GQA kv=8), d_ff=28672, vocab=128256.
The vision tower (ViT + projector) is a STUB per the assignment carve-out:
``input_specs`` provides pre-computed patch embeddings (B, 1024, d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_every=5,
    num_vision_tokens=1024,
    supports_long_context=False,  # full attention; long_500k skipped (DESIGN.md §4)
    source="hf:meta-llama/Llama-3.2-11B-Vision (arch pattern), 90B scale per assignment",
)
