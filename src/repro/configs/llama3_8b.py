"""Llama-3-8B [arXiv:2407.21783] — dense GQA decoder, 128k vocab.

32 layers, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=128256.
long_500k runs via the sliding-window variant (window 8192) — DESIGN.md §4.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    sliding_window=8192,          # used only for the long_500k shape
    supports_long_context=True,
    source="arXiv:2407.21783 (Llama 3), 8B configuration",
)
