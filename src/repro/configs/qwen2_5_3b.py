"""Qwen2.5-3B [hf:Qwen/Qwen2.5-0.5B arch pattern, 3B scale per assignment].

36 layers, d_model=2048, 16 heads (GQA kv=2), d_ff=11008, vocab=151936,
QKV bias.  long_500k via sliding-window variant.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    sliding_window=8192,
    supports_long_context=True,
    source="hf:Qwen/Qwen2.5-0.5B (arch pattern), 3B scale per assignment",
)
