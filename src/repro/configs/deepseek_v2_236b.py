"""DeepSeek-V2-236B [arXiv:2405.04434] — MLA + fine-grained MoE.

60 layers, d_model=5120, 128 heads via multi-head latent attention
(kv_lora_rank=512, q_lora_rank=1536, rope 64 + nope 128, v 128),
160 routed experts top-6 + 2 shared experts, expert d_ff=1536,
vocab=102400; first block uses a dense FFN (d_ff 12288).
"""
from repro.configs.base import ArchConfig, MoEConfig, MLAConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,        # MLA: heads share one latent; kept for bookkeeping
    d_ff=1536,
    vocab_size=102400,
    head_dim=128,
    moe=MoEConfig(num_experts=160, experts_per_token=6, num_shared_experts=2,
                  expert_d_ff=1536, every=1, first_dense_layers=1,
                  dense_d_ff=12288, capacity_factor=1.25),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    supports_long_context=False,  # full attention; long_500k skipped
    source="arXiv:2405.04434 (DeepSeek-V2)",
)
