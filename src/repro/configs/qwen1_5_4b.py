"""Qwen1.5-4B [hf:Qwen/Qwen1.5-0.5B arch pattern, 4B scale per assignment].

40 layers, d_model=2560, 20 heads (MHA: kv=20), d_ff=6912, vocab=151936,
QKV bias.  long_500k via sliding-window variant.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    sliding_window=8192,
    supports_long_context=True,
    source="hf:Qwen/Qwen1.5-0.5B (arch pattern), 4B scale per assignment",
)
