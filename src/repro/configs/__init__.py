"""Config registry: public ``--arch`` ids -> ArchConfig."""
from __future__ import annotations

from repro.configs.base import ArchConfig, InputShape, INPUT_SHAPES  # noqa: F401

from repro.configs import (  # noqa: E402
    xlstm_1_3b,
    llama_3_2_vision_90b,
    whisper_small,
    llama3_8b,
    grok_1_314b,
    qwen2_5_3b,
    olmo_1b,
    qwen1_5_4b,
    deepseek_v2_236b,
    jamba_v0_1_52b,
    bert_base,
)

REGISTRY = {
    "xlstm-1.3b": xlstm_1_3b.CONFIG,
    "llama-3.2-vision-90b": llama_3_2_vision_90b.CONFIG,
    "whisper-small": whisper_small.CONFIG,
    "llama3-8b": llama3_8b.CONFIG,
    "grok-1-314b": grok_1_314b.CONFIG,
    "qwen2.5-3b": qwen2_5_3b.CONFIG,
    "olmo-1b": olmo_1b.CONFIG,
    "qwen1.5-4b": qwen1_5_4b.CONFIG,
    "deepseek-v2-236b": deepseek_v2_236b.CONFIG,
    "jamba-v0.1-52b": jamba_v0_1_52b.CONFIG,
    # the paper's own model
    "bert-base": bert_base.CONFIG,
}

ASSIGNED = [k for k in REGISTRY if k != "bert-base"]


def get_config(arch: str) -> ArchConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch]
