"""Whisper-small [arXiv:2212.04356] — encoder-decoder audio backbone.

12 encoder + 12 decoder layers, d_model=768, 12 heads, d_ff=3072,
vocab=51865.  The mel-spectrogram + conv feature extractor is a STUB per the
assignment carve-out: ``input_specs`` provides frame embeddings
(B, 1500, d_model).  LayerNorm + GELU, learned positions, full attention in
the decoder => long_500k skipped (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, LoRAConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,           # decoder layers
    encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    max_position_embeddings=448 * 80,  # generous learned-position table
    num_audio_frames=1500,
    lora=LoRAConfig(rank=16, alpha=32.0, targets=("q", "v")),
    supports_long_context=False,
    source="arXiv:2212.04356 (Whisper), small configuration",
)
