"""Grok-1-314B [hf:xai-org/grok-1] — MoE, 8 experts top-2.

64 layers, d_model=6144, 48 heads (GQA kv=8), expert d_ff=32768,
vocab=131072, every block is MoE.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    act="gelu",
    moe=MoEConfig(num_experts=8, experts_per_token=2, expert_d_ff=32768,
                  every=1, capacity_factor=1.25),
    supports_long_context=False,  # full attention; long_500k skipped
    source="hf:xai-org/grok-1 model card",
)
